//! The paper's §2.1 headline: exact spectral tuning (one O(N^3) setup,
//! then O(N) per evaluation) versus sparse low-rank baselines that pay
//! O(N m^2) *per evaluation* when the kernel moves under the sweep.
//!
//! For each N and each inducing-fraction rung m/N in {1/32 .. 1/2} the
//! bench measures
//!
//! - `setup_total`   — the exact method's one-time gram + eigensolve,
//! - `spec_eval`     — the exact O(N) eq. 19 score per iterate,
//! - `sor_eval_r*`   — subset-of-regressors score with the reduced
//!   spectrum recomputed per call (the §2.1 sweep regime, O(N m^2)),
//! - `nystrom_eval_r8` — the cheaper Williams–Seeger construction at
//!   m = N/8 (O(m^3 + N m)),
//! - `sor_cached_r8` — the cached-spectrum fast path (spectrum built
//!   once, O(m) per probe; DESIGN.md §13),
//!
//! and derives the **crossover** k* = setup / (sparse_eval - spec_eval):
//! the evaluation count beyond which paying the exact setup wins
//! outright.  The paper's qualitative claim, asserted here, is that k*
//! is finite at every rung (the sparse per-eval cost always exceeds the
//! exact O(N) eval) and shrinks as m/N grows.  Per-rung sparse score
//! error versus the exact eq. 19 value rides along so the cost
//! comparison can't quietly trade away correctness.
//!
//! Writes `BENCH_sparse.json` (gated in CI at N <= 512 against
//! `benches/baselines/BENCH_sparse.json`; the weekly `large-n` workflow
//! runs the N >= 4096 sweep report-only).
//!
//! Options (after `cargo bench --bench sparse_crossover --`):
//!   --sizes 256,512,1024            sweep override
//!   --max-n 512                     cap the sweep (CI smoke uses this)
//!   --iters 3                       sparse-eval repetitions per point

mod bench_common;

use bench_common::*;
use gpml::kernelfn::{gram, Kernel};
use gpml::linalg::{Matrix, SymEigen};
use gpml::sparse::{even_inducing, SparseGp, SparseMethod};
use gpml::spectral::{EigenSystem, HyperParams};
use gpml::util::cli::Args;
use gpml::util::json::Json;
use gpml::util::rng::Rng;
use gpml::util::threadpool;
use gpml::util::timing::{measure, measure_block_stats, Stats, Table};

/// Inducing-fraction rungs: m = N / divisor.
const RUNGS: [(usize, &str); 5] = [(32, "r32"), (16, "r16"), (8, "r8"), (4, "r4"), (2, "r2")];

/// One (N, rung) crossover record for the JSON payload.
struct Crossover {
    n: usize,
    rung: &'static str,
    m: usize,
    sparse_eval_us: f64,
    err_rel: f64,
    k_star: f64,
}

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let default_sizes = [256usize, 512, 1024, 2048, 4096, 8192];
    let mut sizes = args.get_usize_list("sizes", &default_sizes).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    match args.get_usize("max-n", usize::MAX) {
        Ok(cap) => sizes.retain(|&n| n <= cap),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
    if sizes.is_empty() {
        eprintln!("empty sweep after --sizes/--max-n filtering");
        std::process::exit(2);
    }
    let iters = args.get_usize("iters", 0).unwrap_or(0);

    let pooled = threadpool::num_threads();
    let hp = HyperParams::new(0.7, 1.3);
    let kern = Kernel::Rbf { xi2: 1.5 };
    println!(
        "== sparse crossover (paper §2.1): exact O(N^3)+k O(N) vs sparse k O(N m^2) \
         ({pooled} threads) =="
    );

    let mut table = Table::new(&[
        "N",
        "rung",
        "m",
        "setup ms",
        "spec us",
        "sparse ms",
        "err rel",
        "k*",
    ]);
    let mut st_setup: Vec<Stats> = vec![];
    let mut st_spec: Vec<Stats> = vec![];
    let mut st_sor: Vec<Vec<Stats>> = vec![vec![]; RUNGS.len()];
    let mut st_ny8: Vec<Stats> = vec![];
    let mut st_cached8: Vec<Stats> = vec![];
    let mut crossings: Vec<Crossover> = vec![];

    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let x = Matrix::from_fn(n, 4, |_, _| rng.normal());
        let y = rng.normal_vec(n);

        // -- exact side: one-time setup, then O(N) per-iterate evals.
        // The setup is minutes at N = 8192, so repetitions taper with N
        // (the eval series carry the sample spread; the setup enters k*
        // as a one-time numerator).
        let setup_reps = if n <= 512 {
            3
        } else if n <= 2048 {
            2
        } else {
            1
        };
        let mut captured: Option<EigenSystem> = None;
        let setup = measure(0, setup_reps, || {
            let k = gram(kern, &x);
            let eig = SymEigen::new(&k).expect("gram eigensolve");
            captured = Some(EigenSystem::new(&eig, &y));
        });
        let es = captured.expect("setup ran");
        let exact_score = es.score(hp);
        let spec = measure_block_stats(1, rust_iters(n), 5, || {
            std::hint::black_box(es.score(hp));
        });

        for (r, &(div, rung)) in RUNGS.iter().enumerate() {
            let m = (n / div).max(1);
            let sp = SparseGp::new(SparseMethod::Sor, kern, &x, &y, &even_inducing(n, m))
                .expect("sparse build");
            // per-eval recompute cost scales as N m^2: taper repetitions
            // to keep the largest rungs bounded (one eval is minutes at
            // N = 8192, m = N/2)
            let reps = if iters > 0 {
                iters
            } else {
                (200_000_000 / (n * m * m).max(1)).clamp(1, 50)
            };
            let st = measure(0, reps, || {
                std::hint::black_box(sp.score(hp));
            });
            let err_rel = (sp.score(hp) - exact_score).abs() / exact_score.abs().max(1.0);
            // §2.1 ledger: exact = setup + k * spec, sparse = k * eval;
            // they cross at k* = setup / (eval - spec), finite whenever
            // the sparse per-eval cost exceeds the exact O(N) eval
            let k_star = if st.median_us > spec.median_us {
                setup.median_us / (st.median_us - spec.median_us)
            } else {
                f64::INFINITY
            };
            table.row(&[
                n.to_string(),
                rung.to_string(),
                m.to_string(),
                format!("{:.1}", setup.median_us / 1e3),
                format!("{:.2}", spec.median_us),
                format!("{:.2}", st.median_us / 1e3),
                format!("{err_rel:.2e}"),
                if k_star.is_finite() { format!("{k_star:.1}") } else { "never".into() },
            ]);
            crossings.push(Crossover { n, rung, m, sparse_eval_us: st.median_us, err_rel, k_star });
            st_sor[r].push(st);
        }

        // -- the r8 rung again under the two alternative evaluators:
        // Williams–Seeger recompute and the cached-spectrum fast path
        let m8 = (n / 8).max(1);
        let idx8 = even_inducing(n, m8);
        let ny = SparseGp::new(SparseMethod::Nystrom, kern, &x, &y, &idx8).expect("nystrom build");
        let reps8 = if iters > 0 {
            iters
        } else {
            (200_000_000 / (n * m8 * m8).max(1)).clamp(1, 50)
        };
        let st_ny = measure(0, reps8, || {
            std::hint::black_box(ny.score(hp));
        });
        let mut cached = SparseGp::new(SparseMethod::Sor, kern, &x, &y, &idx8).expect("sor build");
        let ces = cached.eigensystem().expect("cached spectrum").clone();
        let st_c = measure_block_stats(1, rust_iters(n), 5, || {
            std::hint::black_box(ces.score(hp));
        });
        st_ny8.push(st_ny);
        st_cached8.push(st_c);
        st_setup.push(setup);
        st_spec.push(spec);
    }
    table.print();

    let nsf: Vec<f64> = sizes.iter().map(|&n| n as f64).collect();
    let spec_med: Vec<f64> = st_spec.iter().map(|s| s.median_us).collect();
    print_fit("spec_eval", &nsf, &spec_med, "tau(N) ~ a + b N (O(N) per iterate)");

    // machine-readable payload FIRST, acceptance asserts after — a
    // failed assert in CI must still leave the artifact for debugging
    // (the upload step runs with `if: always()`)
    let series: Vec<Series> = vec![
        Series { label: "setup_total", stats: &st_setup },
        Series { label: "spec_eval", stats: &st_spec },
        Series { label: "sor_eval_r32", stats: &st_sor[0] },
        Series { label: "sor_eval_r16", stats: &st_sor[1] },
        Series { label: "sor_eval_r8", stats: &st_sor[2] },
        Series { label: "sor_eval_r4", stats: &st_sor[3] },
        Series { label: "sor_eval_r2", stats: &st_sor[4] },
        Series { label: "nystrom_eval_r8", stats: &st_ny8 },
        Series { label: "sor_cached_r8", stats: &st_cached8 },
    ];
    let crossover_json = Json::Arr(
        crossings
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("n", Json::Num(c.n as f64)),
                    ("rung", Json::str(c.rung)),
                    ("m", Json::Num(c.m as f64)),
                    ("m_over_n", Json::Num(c.m as f64 / c.n as f64)),
                    ("sparse_eval_us", Json::Num(c.sparse_eval_us)),
                    ("err_rel", Json::Num(c.err_rel)),
                    // infinite k* ("sparse never loses") encodes as null
                    ("k_star", Json::Num(c.k_star)),
                ])
            })
            .collect(),
    );
    let payload = bench_json(
        "sparse",
        &sizes,
        &series,
        vec![
            ("kernel", Json::str("rbf:1.5")),
            (
                "hp",
                Json::obj(vec![
                    ("sigma2", Json::Num(hp.sigma2)),
                    ("lambda2", Json::Num(hp.lambda2)),
                ]),
            ),
            ("crossover", crossover_json),
        ],
    );
    write_bench_json("sparse", &payload);

    // Acceptance (ISSUE 9): the §2.1 claim, qualitatively.  (1) k* is
    // finite at every rung — a sparse recompute eval costs strictly more
    // than the exact O(N) eval; (2) k* shrinks as m/N grows, checked at
    // the ~256x-separated endpoint rungs so scheduler noise cannot flip
    // the comparison.
    for c in &crossings {
        if c.n >= 256 {
            assert!(
                c.k_star.is_finite() && c.k_star > 0.0,
                "acceptance failed: no finite crossover at N={} {} (m={}): sparse eval \
                 {:.1}us never exceeds the exact O(N) eval",
                c.n,
                c.rung,
                c.m,
                c.sparse_eval_us
            );
        }
    }
    for &n in &sizes {
        if n < 256 {
            continue;
        }
        let at = |rung: &str| {
            crossings
                .iter()
                .find(|c| c.n == n && c.rung == rung)
                .map(|c| c.k_star)
                .expect("rung measured")
        };
        let (coarse, fine) = (at("r32"), at("r2"));
        assert!(
            fine < coarse,
            "acceptance failed: k* did not shrink with m/N at N={n}: \
             k*(m=N/2)={fine:.1} vs k*(m=N/32)={coarse:.1}"
        );
    }
    let last = crossings.len() - 1;
    println!(
        "\n@ N={}: sparse m=N/2 recompute eval {:.1} ms vs exact O(N) eval {:.3} ms — \
         exact wins past k* = {:.1} evaluations (err_rel {:.1e})",
        crossings[last].n,
        crossings[last].sparse_eval_us / 1e3,
        st_spec.last().unwrap().median_us / 1e3,
        crossings[last].k_star,
        crossings[last].err_rel
    );
}

//! §2.1 — comparison against sparse approximations: the spectral method
//! costs O(N^3) + k* O(N); a Nyström/SoR baseline costs k* O(N m^2).
//! The spectral method wins once
//!     k* > t_eigen / (t_nystrom_eval - t_spec_eval)
//! and that threshold shrinks as the sparsity budget m/N grows.  This
//! bench measures the per-eval costs and reports the crossover k* for a
//! sweep of m/N, plus the approximation error the sparse method pays.

mod bench_common;

use std::time::Instant;

use bench_common::*;
use gpml::kernelfn::{gram, Kernel};
use gpml::linalg::{Matrix, SymEigen};
use gpml::sparse::{even_inducing, NystromEvaluator};
use gpml::spectral::{EigenSystem, HyperParams};
use gpml::util::rng::Rng;
use gpml::util::timing::{measure_block, Table};

fn main() {
    println!("== §2.1: spectral (exact) vs Nyström sparse approximation ==");
    let n = 768;
    let hp = HyperParams::new(0.7, 1.3);
    let kern = Kernel::Rbf { xi2: 1.5 };

    let mut rng = Rng::new(7);
    let x = Matrix::from_fn(n, 4, |_, _| rng.normal());
    let y = rng.normal_vec(n);
    let k = gram(kern, &x);

    let t = Instant::now();
    let eig = SymEigen::new(&k).expect("eigensolver");
    let t_eigen = t.elapsed().as_secs_f64();
    let es = EigenSystem::new(&eig, &y);
    let exact = es.score(hp);
    let t_spec_us = measure_block(20, rust_iters(n), || {
        std::hint::black_box(es.score(hp));
    });
    println!("N={n}: eigendecomposition {t_eigen:.3} s, spectral eval {t_spec_us:.2} us, exact score {exact:.4}");

    let mut table = Table::new(&[
        "m",
        "m/N",
        "nystrom us/eval",
        "score |err|",
        "crossover k*",
    ]);
    for &m in &[24usize, 48, 96, 192, 384] {
        let ny = NystromEvaluator::new(kern, &x, &y, &even_inducing(n, m));
        let iters = (200_000 / m).clamp(3, 200);
        let t_ny_us = measure_block(2, iters, || {
            std::hint::black_box(ny.score(hp));
        });
        let err = (ny.score(hp) - exact).abs();
        let crossover = if t_ny_us > t_spec_us {
            format!("{:.0}", t_eigen * 1e6 / (t_ny_us - t_spec_us))
        } else {
            "never".to_string()
        };
        table.row(&[
            m.to_string(),
            format!("{:.3}", m as f64 / n as f64),
            format!("{t_ny_us:.1}"),
            format!("{err:.3e}"),
            crossover,
        ]);
    }
    table.print();
    println!("\npaper: 'the proposed set of identities provides a speed-up ... even with");
    println!("respect to approximate methods, at least if k* exceeds a certain threshold");
    println!("that depends on the sparsity rate m/N' — the crossover column is that");
    println!("threshold; note the sparse method also pays the score |err| column, the");
    println!("exact method pays none.");
}

//! Figure 3: evaluation time of the Hessian (eqs. 26-28) vs N.
//!
//! Paper result: a *piecewise* fit — tau_H ~= 64.04 + 1.39 N for N <= 1024
//! and 1347.81 + 0.13 N above, a kink the authors attribute to MATLAB
//! internals, not to the identities.  Our implementation computes the full
//! fused evaluation (score + Jacobian + Hessian, six accumulators — the
//! form a Newton step actually consumes); we expect a single linear
//! regime with slope ~3x the score slope and report whether any kink
//! appears.  Alongside the stdout table the run writes
//! `BENCH_fig3_hessian.json` for the cross-PR perf trajectory.

mod bench_common;

use bench_common::*;
use gpml::spectral::HyperParams;
use gpml::util::json::Json;
use gpml::util::timing::{linear_fit, measure_block_stats, Stats, Table};

fn main() {
    println!("== Figure 3: Hessian (fused) evaluation time vs N ==");
    let rt = open_runtime();
    let hp = HyperParams::new(0.7, 1.3);

    let mut table = Table::new(&["N", "rust us/eval", "pjrt us/eval"]);
    let (mut ns, mut rust_us, mut pjrt_us) = (vec![], vec![], vec![]);
    let (mut rust_stats, mut pjrt_stats): (Vec<Stats>, Vec<Stats>) = (vec![], vec![]);

    for &n in &PAPER_SWEEP {
        let es = synthetic_eigensystem(n, 20 + n as u64);
        let st_rust = measure_block_stats(50, rust_iters(n), 7, || {
            std::hint::black_box(es.evaluate(hp));
        });
        let t_rust = st_rust.median_us;
        let st_pjrt = rt.as_ref().map(|rt| {
            let ev = rt.evaluator(&es).expect("evaluator");
            measure_block_stats(20, pjrt_iters(n), 3, || {
                std::hint::black_box(ev.try_eval_full(hp).expect("pjrt fused"));
            })
        });
        ns.push(n as f64);
        rust_us.push(t_rust);
        rust_stats.push(st_rust);
        if let Some(st) = &st_pjrt {
            pjrt_us.push(st.median_us);
            pjrt_stats.push(st.clone());
        }
        table.row(&[
            n.to_string(),
            format!("{t_rust:.2}"),
            st_pjrt.map(|st| format!("{:.2}", st.median_us)).unwrap_or_else(|| "-".into()),
        ]);
    }
    table.print();

    print_fit(
        "rust (all N)",
        &ns,
        &rust_us,
        "tau_H ~= 64.04 + 1.39 N (N<=1024); 1347.81 + 0.13 N (N>1024)",
    );

    // piecewise check: fit both halves like the paper did and report the
    // slope change (paper saw ~10x drop; we expect ~none)
    let lo: Vec<usize> = (0..ns.len()).filter(|&i| ns[i] <= 1024.0).collect();
    let hi: Vec<usize> = (0..ns.len()).filter(|&i| ns[i] >= 1024.0).collect();
    let mut extra: Vec<(&str, Json)> = vec![];
    if lo.len() >= 3 && hi.len() >= 3 {
        let (a1, b1, _) = linear_fit(
            &lo.iter().map(|&i| ns[i]).collect::<Vec<_>>(),
            &lo.iter().map(|&i| rust_us[i]).collect::<Vec<_>>(),
        );
        let (a2, b2, _) = linear_fit(
            &hi.iter().map(|&i| ns[i]).collect::<Vec<_>>(),
            &hi.iter().map(|&i| rust_us[i]).collect::<Vec<_>>(),
        );
        println!("piecewise: N<=1024 -> {a1:.2} + {b1:.5} N; N>=1024 -> {a2:.2} + {b2:.5} N");
        println!(
            "slope ratio across the paper's kink: {:.2} (paper saw 0.13/1.39 = 0.09; MATLAB artifact)",
            b2 / b1
        );
        extra.push((
            "piecewise",
            Json::obj(vec![
                ("lo_a_us", Json::Num(a1)),
                ("lo_b_us_per_n", Json::Num(b1)),
                ("hi_a_us", Json::Num(a2)),
                ("hi_b_us_per_n", Json::Num(b2)),
                ("slope_ratio", Json::Num(b2 / b1)),
            ]),
        ));
    }

    let mut series = vec![Series { label: "rust_fused", stats: &rust_stats }];
    if pjrt_stats.len() == PAPER_SWEEP.len() {
        series.push(Series { label: "pjrt_fused", stats: &pjrt_stats });
    }
    let payload = bench_json("fig3_hessian", &PAPER_SWEEP, &series, extra);
    write_bench_json("fig3_hessian", &payload);

    // eq. 44 checkpoint: paper's local step at N=8000 is ~3.56 ms
    if let Some(last) = rust_us.last() {
        println!(
            "\neq. 44 checkpoint @ N=8192: paper ~ 3560 us per local iteration; measured rust {last:.1} us (fused, single pass)"
        );
    }
}

//! Proposition 2.4 — posterior covariance Sigma_c:
//!   eq. (36) needs two O(N^3) inversions;
//!   the spectral form U Q U' costs one Strassen multiply (O(N^2.807))
//!   for the full matrix, or O(N) per requested element for the diagonal.
//! This bench regenerates that three-way comparison (plus the PJRT
//! diag artifact when available).

mod bench_common;

use std::time::Instant;

use bench_common::*;
use gpml::kernelfn::{gram, Kernel};
use gpml::linalg::{gemm, Cholesky, Matrix};
use gpml::spectral::{HyperParams, SpectralGp};
use gpml::util::rng::Rng;
use gpml::util::timing::Table;

/// Dense eq. (36): sigma2 (K + rI)^{-1} K^{-1} via two Cholesky inversions.
/// `k` must be SPD — the caller jitters the Gram matrix, and the spectral
/// path decomposes the *same* jittered matrix, so both sides compute the
/// same well-defined quantity (a raw RBF Gram is numerically singular and
/// K^{-1} is meaningless for either method).
fn dense_sigma_c(k: &Matrix, hp: HyperParams) -> Matrix {
    let mut m = k.clone();
    m.add_diag(hp.sigma2 / hp.lambda2);
    let minv = Cholesky::new(&m).expect("SPD").inverse();
    let kinv = Cholesky::new(k).expect("SPD").inverse();
    let mut out = gemm::matmul(&minv, &kinv);
    out.scale(hp.sigma2);
    out
}

fn main() {
    println!("== Prop. 2.4: posterior covariance Sigma_c ==");
    let rt = open_runtime();
    let hp = HyperParams::new(0.5, 2.0);
    let kern = Kernel::Rbf { xi2: 1.5 };

    let mut table = Table::new(&[
        "N",
        "eq36 dense s",
        "strassen UQU' s",
        "diag-only s",
        "pjrt diag s",
        "max|diff| dense vs spectral",
    ]);

    for &n in &[128usize, 256, 512, 1024] {
        let mut rng = Rng::new(n as u64);
        let x = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let mut k = gram(kern, &x);
        k.add_diag(1e-6 * n as f64); // make K^{-1} well-defined for both paths
        let gp = SpectralGp::fit_from_gram(kern, x.clone(), &k).expect("fit");

        let t = Instant::now();
        let dense = dense_sigma_c(&k, hp);
        let t_dense = t.elapsed().as_secs_f64();

        let t = Instant::now();
        let full = gp.posterior_var_full(hp);
        let t_full = t.elapsed().as_secs_f64();

        let t = Instant::now();
        let diag = gp.posterior_var_diag(hp);
        let t_diag = t.elapsed().as_secs_f64();

        let t_pjrt = rt.as_ref().and_then(|rt| {
            if n > 4096 {
                return None;
            }
            let t = Instant::now();
            let d = rt
                .posterior_var_diag(&gp.eigen().vectors, &gp.eigen().values, hp)
                .ok()?;
            std::hint::black_box(d);
            Some(t.elapsed().as_secs_f64())
        });

        // correctness: diagonal agreement between all paths
        let mut max_diff = 0.0f64;
        for i in 0..n {
            max_diff = max_diff.max((dense[(i, i)] - diag[i]).abs());
            max_diff = max_diff.max((full[(i, i)] - diag[i]).abs());
        }

        table.row(&[
            n.to_string(),
            format!("{t_dense:.3}"),
            format!("{t_full:.3}"),
            format!("{t_diag:.4}"),
            t_pjrt.map(|t| format!("{t:.4}")).unwrap_or_else(|| "-".into()),
            format!("{max_diff:.2e}"),
        ]);
    }
    table.print();
    println!("\npaper: eq. (36) costs two O(N^3) inversions; U Q U' via Strassen is");
    println!("O(N^2.807); interesting elements (the diagonal) are O(N) each.");
}

//! Serving throughput: cold-vs-warm request latency through the real TCP
//! server, demonstrating that the session cache turns the paper's
//! `O(N^3) + k*·O(N)` amortization into steady-state serving behavior.
//!
//! Measured per sweep point, over the wire (parse + dispatch included):
//!
//! - `tune_cold`    — inline tune of a never-seen dataset (pays the full
//!                    Gram + eigendecomposition before tuning);
//! - `tune_warm`    — identical tune against an existing session (zero
//!                    setup work, O(N) per iterate);
//! - `create_warm`  — `create_session` cache hit (fingerprint + lookup);
//! - `evaluate_warm`— one score/Jacobian/Hessian evaluation (pure O(N),
//!                    the smallest servable unit of work).
//!
//! Also reports a multi-client paragraph: 4 concurrent connections
//! hammering warm sessions, as requests/second.
//!
//! Writes `BENCH_serve.json` next to the stdout table.
//!
//! Options (after `cargo bench --bench serve_throughput --`):
//!   --sizes 64,128,256,512   sweep override
//!   --max-n 256              cap the sweep (CI smoke uses this)
//!   --iters 3                timed repetitions per point

mod bench_common;

use bench_common::{bench_json, write_bench_json, Series};
use gpml::coordinator::client::Client;
use gpml::coordinator::protocol::EvaluateRequest;
use gpml::coordinator::server::Server;
use gpml::coordinator::session::SessionTuneRequest;
use gpml::coordinator::{Coordinator, GlobalStrategy, ObjectiveKind, TuneRequest};
use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::spectral::HyperParams;
use gpml::util::cli::Args;
use gpml::util::json::Json;
use gpml::util::timing::{measure, Stats, Table};

const KERNEL: Kernel = Kernel::Rbf { xi2: 2.0 };

fn dataset(n: usize, seed: u64) -> (gpml::linalg::Matrix, Vec<Vec<f64>>) {
    let ds = synthetic(SyntheticSpec { n, p: 4, seed, ..Default::default() }, 1);
    (ds.x, ds.ys)
}

fn tune_request(x: gpml::linalg::Matrix, ys: Vec<Vec<f64>>) -> TuneRequest {
    let mut req = TuneRequest::new(x, ys, KERNEL);
    req.strategy = GlobalStrategy::Grid { points_per_axis: 7 };
    req.objective = ObjectiveKind::Evidence;
    req
}

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let default_sizes = [64usize, 128, 256, 512];
    let mut sizes = args.get_usize_list("sizes", &default_sizes).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    match args.get_usize("max-n", usize::MAX) {
        Ok(cap) => sizes.retain(|&n| n <= cap),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
    if sizes.is_empty() {
        eprintln!("empty sweep after --sizes/--max-n filtering");
        std::process::exit(2);
    }
    let iters = args.get_usize("iters", 3).unwrap_or(3).max(1);

    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).expect("bind");
    let addr = server.addr.to_string();
    println!(
        "== serve throughput: cold vs warm request latency ({} pool workers) ==",
        server.workers()
    );

    let mut table = Table::new(&[
        "N",
        "tune cold ms",
        "tune warm ms",
        "create warm us",
        "evaluate us",
        "cold/warm",
    ]);
    type Sweep = Vec<Stats>;
    let (mut cold, mut warm, mut create, mut eval): (Sweep, Sweep, Sweep, Sweep) =
        (vec![], vec![], vec![], vec![]);

    for &n in &sizes {
        let mut client = Client::connect(&addr).expect("connect");

        // cold tunes: a fresh dataset every repetition, so each request
        // pays the full O(N^3) setup.  Datasets are generated outside the
        // timed closure (synthetic GP sampling is itself super-linear).
        let cold_reqs: Vec<TuneRequest> = (0..iters)
            .map(|i| {
                let (x, ys) = dataset(n, 1_000 * n as u64 + i as u64);
                tune_request(x, ys)
            })
            .collect();
        let mut cold_i = 0;
        let st_cold = measure(0, iters, || {
            client.tune(&cold_reqs[cold_i]).expect("cold tune");
            cold_i += 1;
        });

        // one pinned session for the warm series
        let (x, ys) = dataset(n, 7);
        let id = client.create_session(&x, KERNEL).expect("create");
        let mut sreq = SessionTuneRequest::new(id, ys.clone());
        sreq.strategy = GlobalStrategy::Grid { points_per_axis: 7 };
        sreq.objective = ObjectiveKind::Evidence;
        let st_warm = measure(1, iters, || {
            client.tune_session(&sreq).expect("warm tune");
        });

        let st_create = measure(1, iters, || {
            client.create_session(&x, KERNEL).expect("warm create");
        });

        let ereq = EvaluateRequest {
            session_id: id,
            y: ys[0].clone(),
            hp: HyperParams::new(0.1, 1.0),
            objective: ObjectiveKind::Evidence,
        };
        let st_eval = measure(1, iters.max(10), || {
            client.evaluate(&ereq).expect("evaluate");
        });

        table.row(&[
            n.to_string(),
            format!("{:.2}", st_cold.median_us / 1e3),
            format!("{:.2}", st_warm.median_us / 1e3),
            format!("{:.0}", st_create.median_us),
            format!("{:.0}", st_eval.median_us),
            format!("{:.1}x", st_cold.median_us / st_warm.median_us),
        ]);
        cold.push(st_cold);
        warm.push(st_warm);
        create.push(st_create);
        eval.push(st_eval);
    }
    table.print();

    let last = sizes.len() - 1;
    let amortization = cold[last].median_us / warm[last].median_us;
    println!(
        "\n@ N={}: warm tune {amortization:.1}x faster than cold (the paper's amortized bound)",
        sizes[last]
    );

    // multi-client paragraph: 4 connections hammering warm sessions.
    // Both datasets' sessions are created (warm) before the clock starts,
    // so the measured window contains only warm evaluations.
    let n = sizes[last];
    let clients = 4usize;
    let per_client = 20usize;
    {
        let mut warmup_client = Client::connect(&addr).expect("connect");
        for c in 0..2u64 {
            let (x, _) = dataset(n, 7 + c * 13);
            warmup_client.create_session(&x, KERNEL).expect("pre-create");
        }
    }
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            let (x, ys) = dataset(n, 7 + (c % 2) as u64 * 13);
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                let id = client.create_session(&x, KERNEL).expect("create");
                let ereq = EvaluateRequest {
                    session_id: id,
                    y: ys[0].clone(),
                    hp: HyperParams::new(0.1, 1.0),
                    objective: ObjectiveKind::Evidence,
                };
                for _ in 0..per_client {
                    client.evaluate(&ereq).expect("evaluate");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let rps = (clients * per_client) as f64 / elapsed;
    println!(
        "{clients} clients x {per_client} warm evaluations @ N={n}: {rps:.0} req/s ({:.2}s total)",
        elapsed
    );

    let stats = server.session_stats();
    println!(
        "session cache: {} setups / {} hits / {} misses / {} evictions",
        stats.setups, stats.hits, stats.misses, stats.evictions
    );

    let payload = bench_json(
        "serve",
        &sizes,
        &[
            Series { label: "tune_cold", stats: &cold },
            Series { label: "tune_warm", stats: &warm },
            Series { label: "create_warm", stats: &create },
            Series { label: "evaluate_warm", stats: &eval },
        ],
        vec![
            ("workers", Json::Num(server.workers() as f64)),
            (
                "amortization_at_max_n",
                Json::obj(vec![
                    ("n", Json::Num(sizes[last] as f64)),
                    ("cold_over_warm", Json::Num(amortization)),
                ]),
            ),
            (
                "warm_throughput",
                Json::obj(vec![
                    ("n", Json::Num(n as f64)),
                    ("clients", Json::Num(clients as f64)),
                    ("requests_per_second", Json::Num(rps)),
                ]),
            ),
        ],
    );
    write_bench_json("serve", &payload);
    server.stop();
}

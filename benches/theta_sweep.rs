//! Theta-plane sweep latency through the real TCP server (ISSUE 5
//! acceptance): cold-vs-warm family-cache latency and the parallel-outer
//! wavefront speedup.
//!
//! Three measured series per N:
//!
//! - `cold_outer_serial`   — `tune_theta` on a freshly (re)created
//!                           session with `threads: 1`: every outer
//!                           candidate's O(N^3) setup is built, strictly
//!                           serially;
//! - `cold_outer_parallel` — the identical request with `threads: 4`:
//!                           the *same candidate set* (the wavefront is
//!                           deterministic by construction) fanned
//!                           across the pool.  The ratio of these two
//!                           series is pure outer-loop parallelism —
//!                           inside a pool worker the per-setup
//!                           eigensolver runs inline-serial either way;
//! - `warm`                — the identical request again on the live
//!                           session: every probe hits the eigen-family
//!                           cache (`setups_built: 0` asserted).
//!
//! Plus two ARD variants per N (PR 6 vector-theta engine): a cold 2-D
//! coordinate-descent wavefront over a `rbf-ard` family, without
//! (`ard_cold_wavefront`) and with (`ard_cold_newton`) the exact-Hessian
//! Newton inner refinement — the Newton delta is the cost of the O(N)
//! inner polish against the O(N^3)-dominated outer sweep.
//!
//! The first three must return **bitwise-identical** outputs (asserted
//! on the serialized `outputs` JSON, which uses shortest-round-trip
//! floats).
//! Acceptance, enforced at N >= 512 on >= 4-way hardware: the parallel
//! outer wavefront is >= 2x faster than the serial one.
//!
//! Writes `BENCH_theta.json` next to the stdout table.
//!
//! Options (after `cargo bench --bench theta_sweep --`):
//!   --sizes 64,128,256,512   sweep override
//!   --max-n 128              cap the sweep (CI smoke uses this)
//!   --iters 3                timed repetitions per point
//!   --outer 16               outer candidate budget per sweep

mod bench_common;

use bench_common::{bench_json, write_bench_json, Series};
use gpml::coordinator::client::Client;
use gpml::coordinator::server::Server;
use gpml::coordinator::session::ThetaTuneRequest;
use gpml::coordinator::{Coordinator, ObjectiveKind};
use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::{Kernel, ThetaVec};
use gpml::optim::{RefineKind, ThetaSearch};
use gpml::util::cli::Args;
use gpml::util::json::Json;
use gpml::util::timing::{Stats, Table};

const KERNEL: Kernel = Kernel::Rbf { xi2: 2.0 };

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let default_sizes = [64usize, 128, 256, 512];
    let mut sizes = args.get_usize_list("sizes", &default_sizes).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    match args.get_usize("max-n", usize::MAX) {
        Ok(cap) => sizes.retain(|&n| n <= cap),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
    if sizes.is_empty() {
        eprintln!("empty sweep after --sizes/--max-n filtering");
        std::process::exit(2);
    }
    let iters = args.get_usize("iters", 3).unwrap_or(3).max(1);
    let outer = args.get_usize("outer", 16).unwrap_or(16).max(8);

    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).expect("bind");
    let addr = server.addr.to_string();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "== theta sweep: cold serial vs cold parallel-outer vs warm family cache \
         ({} pool workers, {hw}-way hardware) ==",
        server.workers()
    );

    let mut table = Table::new(&[
        "N",
        "cold t1 ms",
        "cold t4 ms",
        "warm ms",
        "t1/t4",
        "cold/warm",
        "ard ms",
        "ard+newton ms",
    ]);
    type Sweep = Vec<Stats>;
    let (mut cold_t1, mut cold_t4, mut warm): (Sweep, Sweep, Sweep) = (vec![], vec![], vec![]);
    let (mut ard_wave, mut ard_newton): (Sweep, Sweep) = (vec![], vec![]);
    let (mut speedup_outer, mut speedup_warm) = (0.0f64, 0.0f64);

    for &n in &sizes {
        let mut client = Client::connect(&addr).expect("connect");
        let spec = SyntheticSpec { n, p: 4, seed: 13, kernel: KERNEL, ..Default::default() };
        let ds = synthetic(spec, 1);

        let make_req = |id: u64, threads: usize| {
            let mut req = ThetaTuneRequest::new(id, ds.ys.clone());
            req.theta_range = (0.2, 20.0);
            req.outer_iters = outer;
            req.search = ThetaSearch::Wavefront { width: 8 };
            req.inner_grid = 7;
            req.objective = ObjectiveKind::Evidence;
            req.threads = threads;
            req
        };

        // one timed cold sweep: recreate the session (purging its family
        // cache) outside the timed window, then time `tune_theta`
        let cold_run = |client: &mut Client, old: &mut Option<u64>, threads: usize| {
            if let Some(id) = old.take() {
                client.drop_session(id).expect("drop");
            }
            let id = client.create_session(&ds.x, KERNEL).expect("create");
            *old = Some(id);
            let t0 = std::time::Instant::now();
            let res = client.tune_theta(&make_req(id, threads)).expect("tune_theta");
            let us = t0.elapsed().as_secs_f64() * 1e6;
            let built = res.get("setups_built").and_then(Json::as_usize).unwrap_or(0);
            assert!(built > 0, "cold sweep must build setups");
            (us, res.get("outputs").unwrap().to_string())
        };

        let mut sess: Option<u64> = None;
        let mut t1_samples = Vec::new();
        let mut t1_outputs = String::new();
        for _ in 0..iters {
            let (us, outs) = cold_run(&mut client, &mut sess, 1);
            t1_samples.push(us);
            t1_outputs = outs;
        }
        let mut t4_samples = Vec::new();
        let mut t4_outputs = String::new();
        for _ in 0..iters {
            let (us, outs) = cold_run(&mut client, &mut sess, 4);
            t4_samples.push(us);
            t4_outputs = outs;
        }
        assert_eq!(
            t1_outputs, t4_outputs,
            "serial and parallel outer sweeps must be bitwise identical"
        );

        // warm: the last cold sweep left the family populated
        let id = sess.expect("live session");
        let mut warm_samples = Vec::new();
        let mut warm_outputs = String::new();
        for _ in 0..iters {
            let t0 = std::time::Instant::now();
            let res = client.tune_theta(&make_req(id, 4)).expect("warm tune_theta");
            warm_samples.push(t0.elapsed().as_secs_f64() * 1e6);
            assert_eq!(
                res.get("setups_built").and_then(Json::as_usize),
                Some(0),
                "warm sweep must build nothing"
            );
            warm_outputs = res.get("outputs").unwrap().to_string();
        }
        assert_eq!(
            warm_outputs, t4_outputs,
            "warm and cold sweeps must be bitwise identical"
        );

        // ARD variants (PR 6): a cold 2-D coordinate-descent wavefront
        // over the same outer budget, without and with the Newton polish
        let ard_kernel = Kernel::RbfArd { xi2: ThetaVec::splat(2, 2.0) };
        let ard_ds = synthetic(
            SyntheticSpec { n, p: 2, seed: 13, kernel: ard_kernel, ..Default::default() },
            1,
        );
        let ard_req = |id: u64, refine: RefineKind| {
            let mut req = ThetaTuneRequest::new(id, ard_ds.ys.clone());
            req.theta_ranges = vec![(0.2, 20.0), (0.2, 20.0)];
            req.outer_iters = outer;
            req.search = ThetaSearch::Wavefront { width: 8 };
            req.inner_grid = 7;
            req.objective = ObjectiveKind::Evidence;
            req.refine = refine;
            req.threads = 4;
            req
        };
        let mut ard_sess: Option<u64> = None;
        let mut ard_cold_run = |client: &mut Client, refine: RefineKind| {
            if let Some(id) = ard_sess.take() {
                client.drop_session(id).expect("drop ard");
            }
            let id = client.create_session(&ard_ds.x, ard_kernel).expect("create ard");
            ard_sess = Some(id);
            let t0 = std::time::Instant::now();
            let res = client.tune_theta(&ard_req(id, refine)).expect("ard tune_theta");
            let us = t0.elapsed().as_secs_f64() * 1e6;
            let built = res.get("setups_built").and_then(Json::as_usize).unwrap_or(0);
            assert!(built > 0, "cold ARD sweep must build setups");
            us
        };
        let mut ardw_samples = Vec::new();
        for _ in 0..iters {
            ardw_samples.push(ard_cold_run(&mut client, RefineKind::None));
        }
        let mut ardn_samples = Vec::new();
        for _ in 0..iters {
            ardn_samples.push(ard_cold_run(&mut client, RefineKind::Newton));
        }
        if let Some(id) = ard_sess.take() {
            client.drop_session(id).expect("drop ard");
        }

        let (s1, s4, sw, saw, san) = (
            Stats::from_samples(t1_samples),
            Stats::from_samples(t4_samples),
            Stats::from_samples(warm_samples),
            Stats::from_samples(ardw_samples),
            Stats::from_samples(ardn_samples),
        );
        speedup_outer = s1.median_us / s4.median_us;
        speedup_warm = s4.median_us / sw.median_us;
        table.row(&[
            n.to_string(),
            format!("{:.2}", s1.median_us / 1e3),
            format!("{:.2}", s4.median_us / 1e3),
            format!("{:.2}", sw.median_us / 1e3),
            format!("{speedup_outer:.1}x"),
            format!("{speedup_warm:.1}x"),
            format!("{:.2}", saw.median_us / 1e3),
            format!("{:.2}", san.median_us / 1e3),
        ]);
        cold_t1.push(s1);
        cold_t4.push(s4);
        warm.push(sw);
        ard_wave.push(saw);
        ard_newton.push(san);
    }
    table.print();

    let last = sizes.len() - 1;
    println!(
        "\n@ N={}: parallel outer wavefront {speedup_outer:.1}x over serial, warm sweep \
         {speedup_warm:.1}x over cold (acceptance floor at N=512: 2x outer speedup)",
        sizes[last]
    );
    // ISSUE-5 acceptance: enforced, not just printed.  Same-machine
    // relative ratio; skipped below 4-way hardware (no parallelism to
    // measure) and below N=512 (CI's reduced smoke).
    if sizes[last] >= 512 && hw >= 4 {
        assert!(
            speedup_outer >= 2.0,
            "acceptance failed: parallel outer wavefront only {speedup_outer:.1}x faster \
             than serial at N={} (floor: 2x)",
            sizes[last]
        );
    }
    let stats = server.session_stats();
    println!(
        "session cache: {} setups / {} theta hits / {} theta misses / {} theta entries",
        stats.setups, stats.theta_hits, stats.theta_misses, stats.theta_entries
    );

    let payload = bench_json(
        "theta",
        &sizes,
        &[
            Series { label: "cold_outer_serial", stats: &cold_t1 },
            Series { label: "cold_outer_parallel", stats: &cold_t4 },
            Series { label: "warm", stats: &warm },
            Series { label: "ard_cold_wavefront", stats: &ard_wave },
            Series { label: "ard_cold_newton", stats: &ard_newton },
        ],
        vec![
            ("workers", Json::Num(server.workers() as f64)),
            ("outer_budget", Json::Num(outer as f64)),
            ("wavefront_width", Json::Num(8.0)),
            (
                "parallel_outer_speedup_at_max_n",
                Json::obj(vec![
                    ("n", Json::Num(sizes[last] as f64)),
                    ("serial_over_parallel", Json::Num(speedup_outer)),
                ]),
            ),
            (
                "warm_speedup_at_max_n",
                Json::obj(vec![
                    ("n", Json::Num(sizes[last] as f64)),
                    ("cold_over_warm", Json::Num(speedup_warm)),
                ]),
            ),
            ("warm_cold_bitwise_identical", Json::Bool(true)),
        ],
    );
    write_bench_json("theta", &payload);
    server.stop();
}

//! Streaming-update throughput: cold-refit vs incremental-update latency
//! through the real TCP server (ISSUE 4 acceptance: incremental at
//! N=512 at least 5x faster than a cold refit).
//!
//! The scenario is streaming production traffic: a session holds N
//! observations and one (or a small batch) more arrives.  Two ways to
//! serve it:
//!
//! - `cold_refit`   — `create_session` of the full N+1 dataset from
//!                    scratch (a fresh dataset per repetition, so every
//!                    request pays the whole Gram + eigendecomposition);
//! - `update_1`     — `update_session` appending one row to a live
//!                    session (rank-one spectral refresh, zero O(N^3));
//! - `update_batch4`— `update_session` appending 4 rows at once.
//!
//! Repeated updates grow their session (N, N+1, N+2, ...) — that is the
//! streaming regime itself, so the growth stays in the timed series; the
//! per-point iteration counts are kept below the fallback budget so the
//! whole series is genuinely incremental (asserted from the responses).
//!
//! Writes `BENCH_update.json` next to the stdout table.
//!
//! Options (after `cargo bench --bench update_throughput --`):
//!   --sizes 64,128,256,512   sweep override
//!   --max-n 256              cap the sweep (CI smoke uses this)
//!   --iters 5                timed repetitions per point

mod bench_common;

use bench_common::{bench_json, write_bench_json, Series};
use gpml::coordinator::client::Client;
use gpml::coordinator::server::Server;
use gpml::coordinator::Coordinator;
use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::linalg::Matrix;
use gpml::util::cli::Args;
use gpml::util::json::Json;
use gpml::util::rng::Rng;
use gpml::util::timing::{measure, Stats, Table};

const KERNEL: Kernel = Kernel::Rbf { xi2: 2.0 };

fn dataset(n: usize, seed: u64) -> Matrix {
    synthetic(SyntheticSpec { n, p: 4, seed, kernel: KERNEL, ..Default::default() }, 1).x
}

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let default_sizes = [64usize, 128, 256, 512];
    let mut sizes = args.get_usize_list("sizes", &default_sizes).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    match args.get_usize("max-n", usize::MAX) {
        Ok(cap) => sizes.retain(|&n| n <= cap),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
    if sizes.is_empty() {
        eprintln!("empty sweep after --sizes/--max-n filtering");
        std::process::exit(2);
    }
    let iters = args.get_usize("iters", 5).unwrap_or(5).max(1);

    let server = Server::start("127.0.0.1:0", Coordinator::rust_only).expect("bind");
    let addr = server.addr.to_string();
    println!(
        "== update throughput: cold refit vs rank-one refresh ({} pool workers) ==",
        server.workers()
    );

    let mut table = Table::new(&[
        "N",
        "cold refit ms",
        "update(1) ms",
        "update(4) ms",
        "cold/update(1)",
    ]);
    type Sweep = Vec<Stats>;
    let (mut cold, mut upd1, mut upd4): (Sweep, Sweep, Sweep) = (vec![], vec![], vec![]);

    for &n in &sizes {
        let mut client = Client::connect(&addr).expect("connect");
        let mut rng = Rng::new(9_000 + n as u64);

        // cold refits: a fresh N+1 dataset every repetition
        let cold_xs: Vec<Matrix> =
            (0..iters).map(|i| dataset(n + 1, 5_000 * n as u64 + i as u64)).collect();
        let mut cold_i = 0;
        let st_cold = measure(0, iters, || {
            client.create_session(&cold_xs[cold_i], KERNEL).expect("cold create");
            cold_i += 1;
        });

        // incremental single-row updates against one live session.  The
        // default fallback budget is 64 corrections = 32 appended rows;
        // warmup(1) + iters single rows stay under it for iters <= 31.
        let single_id = client.create_session(&dataset(n, 7 * n as u64), KERNEL).expect("create");
        let st_upd1 = measure(1, iters.min(31), || {
            let row = Matrix::from_fn(1, 4, |_, _| rng.normal());
            let res = client.update_session(single_id, &row, 0).expect("update");
            assert_eq!(
                res.get("incremental").and_then(Json::as_bool),
                Some(true),
                "series must stay incremental; shrink --iters"
            );
        });

        // batched updates (4 rows per request) against a second session
        let batch_id = client.create_session(&dataset(n, 11 * n as u64), KERNEL).expect("create");
        let st_upd4 = measure(1, iters.min(7), || {
            let rows = Matrix::from_fn(4, 4, |_, _| rng.normal());
            let res = client.update_session(batch_id, &rows, 0).expect("update");
            assert_eq!(res.get("incremental").and_then(Json::as_bool), Some(true));
        });

        table.row(&[
            n.to_string(),
            format!("{:.2}", st_cold.median_us / 1e3),
            format!("{:.2}", st_upd1.median_us / 1e3),
            format!("{:.2}", st_upd4.median_us / 1e3),
            format!("{:.1}x", st_cold.median_us / st_upd1.median_us),
        ]);
        cold.push(st_cold);
        upd1.push(st_upd1);
        upd4.push(st_upd4);
    }
    table.print();

    let last = sizes.len() - 1;
    let speedup = cold[last].median_us / upd1[last].median_us;
    println!(
        "\n@ N={}: incremental update {speedup:.1}x faster than a cold refit \
         (acceptance floor at N=512: 5x)",
        sizes[last]
    );
    // ISSUE-4 acceptance: enforced, not just printed.  The ratio is
    // same-machine relative, so it is robust to slow hardware; CI's
    // reduced --max-n 256 smoke intentionally skips it.
    if sizes[last] >= 512 {
        assert!(
            speedup >= 5.0,
            "acceptance failed: incremental update only {speedup:.1}x faster than a cold \
             refit at N={} (floor: 5x)",
            sizes[last]
        );
    }
    let stats = server.session_stats();
    println!(
        "session cache: {} setups / {} updates / {} hits / {} misses",
        stats.setups, stats.updates, stats.hits, stats.misses
    );

    let payload = bench_json(
        "update",
        &sizes,
        &[
            Series { label: "cold_refit", stats: &cold },
            Series { label: "update_1", stats: &upd1 },
            Series { label: "update_batch4", stats: &upd4 },
        ],
        vec![
            ("workers", Json::Num(server.workers() as f64)),
            (
                "incremental_speedup_at_max_n",
                Json::obj(vec![
                    ("n", Json::Num(sizes[last] as f64)),
                    ("cold_over_update", Json::Num(speedup)),
                ]),
            ),
        ],
    );
    write_bench_json("update", &payload);
    server.stop();
}

//! Figure 1: evaluation time of the score function L_y (eq. 19) vs N.
//!
//! Paper result: tau_L(N) ~= 42.26 + 0.05 N [us] — a flat dispatch
//! overhead plus ~0.05 us per eigenvalue.  We report the same series for
//! (a) the pure-rust O(N) evaluator and (b) the PJRT score artifact with
//! staged buffers, and fit tau(N) = a + b N to each.  Alongside the
//! stdout table the run writes `BENCH_fig1_score.json` (sweep, medians,
//! percentiles, fit, pool width) for the cross-PR perf trajectory.

mod bench_common;

use bench_common::*;
use gpml::spectral::HyperParams;
use gpml::util::timing::{measure_block_stats, Stats, Table};

fn main() {
    println!("== Figure 1: score evaluation time vs N ==");
    let rt = open_runtime();
    let hp = HyperParams::new(0.7, 1.3);

    let mut table = Table::new(&["N", "rust us/eval", "pjrt us/eval"]);
    let (mut ns, mut rust_us, mut pjrt_us) = (vec![], vec![], vec![]);
    let (mut rust_stats, mut pjrt_stats): (Vec<Stats>, Vec<Stats>) = (vec![], vec![]);

    for &n in &PAPER_SWEEP {
        let es = synthetic_eigensystem(n, n as u64);
        let st_rust = measure_block_stats(50, rust_iters(n), 7, || {
            std::hint::black_box(es.score(hp));
        });
        let t_rust = st_rust.median_us;
        let st_pjrt = rt.as_ref().map(|rt| {
            let ev = rt.evaluator(&es).expect("evaluator");
            measure_block_stats(20, pjrt_iters(n), 3, || {
                std::hint::black_box(ev.try_eval(hp).expect("pjrt eval"));
            })
        });
        ns.push(n as f64);
        rust_us.push(t_rust);
        rust_stats.push(st_rust);
        if let Some(st) = &st_pjrt {
            pjrt_us.push(st.median_us);
            pjrt_stats.push(st.clone());
        }
        table.row(&[
            n.to_string(),
            format!("{t_rust:.2}"),
            st_pjrt.map(|st| format!("{:.2}", st.median_us)).unwrap_or_else(|| "-".into()),
        ]);
    }
    table.print();

    print_fit("rust", &ns, &rust_us, "tau_L(N) ~= 42.26 + 0.05 N [us]");
    if pjrt_us.len() == ns.len() {
        print_fit("pjrt", &ns, &pjrt_us, "tau_L(N) ~= 42.26 + 0.05 N [us]");
    }

    let mut series = vec![Series { label: "rust", stats: &rust_stats }];
    if pjrt_stats.len() == PAPER_SWEEP.len() {
        series.push(Series { label: "pjrt", stats: &pjrt_stats });
    }
    let payload = bench_json("fig1_score", &PAPER_SWEEP, &series, vec![]);
    write_bench_json("fig1_score", &payload);

    // eq. 45 checkpoint: at N ~= 8000 the paper reports ~440 us per global
    // iteration (score only)
    if let Some(last) = rust_us.last() {
        println!("\neq. 45 checkpoint @ N=8192: paper ~ 440 us higher-level; measured rust {last:.1} us");
    }
}

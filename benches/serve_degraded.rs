//! Degraded-mode serving (requires `--features fault-inject`): warm
//! per-request latency while a seeded fraction of pool jobs panics,
//! versus the same traffic on a healthy server (DESIGN.md §11).
//!
//! The claim under test: panic isolation + worker respawn keep the
//! *healthy* requests' latency flat — a faulted neighbor costs its own
//! request, not the pool.  Measured per sweep point, over the wire:
//!
//! - `evaluate_healthy`  — warm evaluate, no faults armed;
//! - `evaluate_degraded` — the same traffic with `WorkerPanic` armed at
//!                         1-in-10 (each firing kills a worker mid-job;
//!                         the supervisor respawns it).  Faulted requests
//!                         are counted and their error responses timed
//!                         like any other response.
//!
//! After the burst the bench asserts the pool is at full strength (all
//! respawns happened, concurrent healthy traffic completes).
//!
//! Writes `BENCH_degraded.json` next to the stdout table.
//!
//! Options (after `cargo bench --bench serve_degraded --`):
//!   --sizes 64,128           sweep override
//!   --max-n 128              cap the sweep (CI smoke uses this)
//!   --iters 40               timed requests per series

mod bench_common;

use bench_common::{bench_json, write_bench_json, Series};
use gpml::coordinator::client::Client;
use gpml::coordinator::protocol::{self, EvaluateRequest};
use gpml::coordinator::server::{Server, ServerOptions};
use gpml::coordinator::{Coordinator, ObjectiveKind};
use gpml::data::{synthetic, SyntheticSpec};
use gpml::faults::inject::{self, FaultPoint};
use gpml::kernelfn::Kernel;
use gpml::spectral::HyperParams;
use gpml::util::cli::Args;
use gpml::util::json::Json;
use gpml::util::timing::{measure, Stats, Table};

const KERNEL: Kernel = Kernel::Rbf { xi2: 2.0 };

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let default_sizes = [64usize, 128];
    let mut sizes = args.get_usize_list("sizes", &default_sizes).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    match args.get_usize("max-n", usize::MAX) {
        Ok(cap) => sizes.retain(|&n| n <= cap),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
    if sizes.is_empty() {
        eprintln!("empty sweep after --sizes/--max-n filtering");
        std::process::exit(2);
    }
    let iters = args.get_usize("iters", 40).unwrap_or(40).max(10);

    let opts = ServerOptions { workers: 2, ..Default::default() };
    let server = Server::start_with("127.0.0.1:0", opts, Coordinator::rust_only).expect("bind");
    let addr = server.addr.to_string();
    println!(
        "== degraded serving: warm evaluate latency, healthy vs 10% worker panics \
         ({} pool workers) ==",
        server.workers()
    );

    let mut table =
        Table::new(&["N", "healthy us", "degraded us", "degraded/healthy", "faulted reqs"]);
    let (mut healthy, mut degraded): (Vec<Stats>, Vec<Stats>) = (vec![], vec![]);
    let mut total_faulted = 0u64;

    for &n in &sizes {
        inject::reset();
        let mut client = Client::connect(&addr).expect("connect");
        let ds = synthetic(SyntheticSpec { n, p: 4, seed: 7, ..Default::default() }, 1);
        let id = client.create_session(&ds.x, KERNEL).expect("create");
        let ereq = EvaluateRequest {
            session_id: id,
            y: ds.ys[0].clone(),
            hp: HyperParams::new(0.1, 1.0),
            objective: ObjectiveKind::Evidence,
        };
        let line = protocol::evaluate_json(&ereq);

        let st_healthy = measure(5, iters, || {
            client.evaluate(&ereq).expect("healthy evaluate");
        });

        // 1-in-10 pool jobs panic their worker mid-dispatch; the faulted
        // request's error response is timed like any success (raw, not
        // checked, so the bench sees the degradation instead of dying)
        inject::arm(FaultPoint::WorkerPanic, 10, u64::MAX);
        let mut faulted = 0u64;
        let st_degraded = measure(0, iters, || {
            let v = client.raw(&line).expect("degraded evaluate transport");
            if v.get("ok").and_then(Json::as_bool) != Some(true) {
                faulted += 1;
            }
        });
        inject::reset();
        total_faulted += faulted;

        table.row(&[
            n.to_string(),
            format!("{:.0}", st_healthy.median_us),
            format!("{:.0}", st_degraded.median_us),
            format!("{:.2}x", st_degraded.median_us / st_healthy.median_us),
            format!("{faulted}/{iters}"),
        ]);
        healthy.push(st_healthy);
        degraded.push(st_degraded);
    }
    table.print();

    // post-burst: the pool must be at full strength — every panicked
    // worker respawned, and concurrent healthy traffic completes
    let respawns = server.session_stats().faults.worker_respawns;
    let handles: Vec<_> = (0..4)
        .map(|i| {
            let addr = addr.clone();
            let n = sizes[0];
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let ds = synthetic(
                    SyntheticSpec { n, p: 4, seed: 100 + i, ..Default::default() },
                    1,
                );
                let id = c.create_session(&ds.x, KERNEL).expect("create");
                let ereq = EvaluateRequest {
                    session_id: id,
                    y: ds.ys[0].clone(),
                    hp: HyperParams::new(0.1, 1.0),
                    objective: ObjectiveKind::Evidence,
                };
                c.evaluate(&ereq).expect("post-burst evaluate");
            })
        })
        .collect();
    for h in handles {
        h.join().expect("post-burst client");
    }
    println!(
        "\npool healed: {respawns} worker respawn(s) over {total_faulted} faulted request(s); \
         4 concurrent clients served post-burst"
    );
    assert!(
        total_faulted == 0 || respawns > 0,
        "faults fired but no worker respawn was recorded"
    );

    let payload = bench_json(
        "degraded",
        &sizes,
        &[
            Series { label: "evaluate_healthy", stats: &healthy },
            Series { label: "evaluate_degraded", stats: &degraded },
        ],
        vec![
            ("faulted_requests", Json::Num(total_faulted as f64)),
            ("worker_respawns", Json::Num(respawns as f64)),
        ],
    );
    write_bench_json("degraded", &payload);
    server.stop();
}

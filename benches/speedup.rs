//! §2.1 — the speed-up claim: tau0/tau1 = O(min{k*, N^2}).
//!
//! For each N we measure (a) one naive O(N^3) score+Jacobian evaluation,
//! (b) the one-time eigendecomposition, (c) one spectral O(N) fused
//! evaluation — then report the end-to-end tuning ratio
//!     tau0 / tau1 = (k* t_naive) / (t_eigen + k* t_spec)
//! across the range of k* the paper discusses ("in practice ... in the
//! hundreds"), plus one *actual* full tune with its measured k*.

mod bench_common;

use std::time::Instant;

use bench_common::*;
use gpml::kernelfn::{gram, Kernel};
use gpml::linalg::{Matrix, SymEigen};
use gpml::naive::NaiveEvaluator;
use gpml::optim::{self, Bounds, PsoOptions};
use gpml::spectral::{EigenSystem, HyperParams};
use gpml::util::json::Json;
use gpml::util::rng::Rng;
use gpml::util::timing::{measure_block, Table};

fn main() {
    println!("== §2.1: tuning speed-up naive vs spectral ==");
    let hp = HyperParams::new(0.7, 1.3);
    let k_stars = [10usize, 100, 300, 1000];
    let sweep = [128usize, 256, 512, 1024];
    let (mut naive_s, mut eigen_s, mut spec_us) = (vec![], vec![], vec![]);
    let mut ratio_rows: Vec<Json> = vec![];

    let mut table = Table::new(&[
        "N",
        "t_naive s/eval",
        "t_eigen s",
        "t_spec us/eval",
        "ratio k*=10",
        "ratio k*=100",
        "ratio k*=300",
        "ratio k*=1000",
    ]);

    for &n in &sweep {
        let mut rng = Rng::new(n as u64);
        let x = Matrix::from_fn(n, 4, |_, _| rng.normal());
        let y = rng.normal_vec(n);
        let k = gram(Kernel::Rbf { xi2: 1.5 }, &x);

        // (a) naive per-iteration cost (score + Jacobian, as §1.1 costs it)
        let naive = NaiveEvaluator::new(k.clone(), y.clone());
        let t0 = Instant::now();
        let iters_naive = if n <= 256 { 3 } else { 1 };
        for _ in 0..iters_naive {
            std::hint::black_box(naive.score_grad(hp));
        }
        let t_naive = t0.elapsed().as_secs_f64() / iters_naive as f64;

        // (b) the one-time O(N^3) overhead
        let t1 = Instant::now();
        let eig = SymEigen::new(&k).expect("eigensolver");
        let t_eigen = t1.elapsed().as_secs_f64();

        // (c) spectral per-iteration cost (fused score+jac+hess)
        let es = EigenSystem::new(&eig, &y);
        let t_spec_us = measure_block(50, rust_iters(n), || {
            std::hint::black_box(es.evaluate(hp));
        });
        let t_spec = t_spec_us * 1e-6;

        naive_s.push(t_naive);
        eigen_s.push(t_eigen);
        spec_us.push(t_spec_us);
        ratio_rows.push(Json::arr_f64(
            &k_stars
                .iter()
                .map(|&k| (k as f64 * t_naive) / (t_eigen + k as f64 * t_spec))
                .collect::<Vec<_>>(),
        ));
        let ratios: Vec<String> = k_stars
            .iter()
            .map(|&k| {
                let tau0 = k as f64 * t_naive;
                let tau1 = t_eigen + k as f64 * t_spec;
                format!("{:.1}x", tau0 / tau1)
            })
            .collect();
        table.row(&[
            n.to_string(),
            format!("{t_naive:.3}"),
            format!("{t_eigen:.3}"),
            format!("{t_spec_us:.2}"),
            ratios[0].clone(),
            ratios[1].clone(),
            ratios[2].clone(),
            ratios[3].clone(),
        ]);
    }
    table.print();
    println!("\npaper: tau0/tau1 = O(min {{k*, N^2}}) — ratios grow ~linearly in k* until");
    println!("the eigendecomposition amortizes, then saturate at t_naive/t_spec.");

    // one actual tune with its real k*
    let n = 512;
    let mut rng = Rng::new(999);
    let x = Matrix::from_fn(n, 4, |_, _| rng.normal());
    let y = rng.normal_vec(n);
    let k = gram(Kernel::Rbf { xi2: 1.5 }, &x);
    let t = Instant::now();
    let eig = SymEigen::new(&k).unwrap();
    let t_eigen = t.elapsed().as_secs_f64();
    let mut es = EigenSystem::new(&eig, &y);
    let t = Instant::now();
    let global = optim::pso_search(
        &mut es,
        Bounds::default(),
        PsoOptions { particles: 64, iterations: 25, ..Default::default() },
    );
    let refined = optim::newton_refine(&mut es, global.hp, Bounds::default(), Default::default());
    let t_tune = t.elapsed().as_secs_f64();
    let k_star = global.evals + refined.evals;
    let naive = NaiveEvaluator::new(k, y);
    let t = Instant::now();
    let _ = naive.score_grad(hp);
    let t_naive = t.elapsed().as_secs_f64();
    println!("\nactual tune @ N={n}: k* = {k_star} evaluations, tune {t_tune:.3} s + eigen {t_eigen:.3} s");
    println!(
        "projected naive at same k*: {:.1} s  ->  end-to-end speed-up {:.0}x",
        t_naive * k_star as f64,
        (t_naive * k_star as f64) / (t_eigen + t_tune)
    );

    // machine-readable trajectory record (single-shot timings, so this
    // payload is hand-assembled rather than going through bench_json's
    // Stats series)
    let payload = Json::obj(vec![
        ("bench", Json::str("speedup")),
        ("threads", Json::Num(gpml::util::threadpool::num_threads() as f64)),
        ("ns", Json::arr_f64(&sweep.iter().map(|&n| n as f64).collect::<Vec<_>>())),
        ("k_stars", Json::arr_f64(&k_stars.iter().map(|&k| k as f64).collect::<Vec<_>>())),
        ("naive_s_per_eval", Json::arr_f64(&naive_s)),
        ("eigen_s", Json::arr_f64(&eigen_s)),
        ("spectral_us_per_eval", Json::arr_f64(&spec_us)),
        ("ratio_by_kstar", Json::Arr(ratio_rows)),
        (
            "actual_tune",
            Json::obj(vec![
                ("n", Json::Num(n as f64)),
                ("k_star", Json::Num(k_star as f64)),
                ("tune_s", Json::Num(t_tune)),
                ("eigen_s", Json::Num(t_eigen)),
                (
                    "end_to_end_speedup",
                    Json::Num((t_naive * k_star as f64) / (t_eigen + t_tune)),
                ),
            ]),
        ),
    ]);
    write_bench_json("speedup", &payload);
}

#![allow(dead_code)] // shared across multiple bench binaries; each uses a subset
//! Shared helpers for the paper-figure benches: synthetic eigensystems
//! with kernel-like decaying spectra, the N sweep, and output formatting.
//!
//! The figures time *per-iterate evaluation* given the eigendecomposition
//! (exactly what the paper's §3 measures: "the average execution time of
//! these quantities"), so the eigensystem here is synthesized directly —
//! a geometric spectrum matching what RBF Gram matrices produce — rather
//! than paying an O(N^3) decomposition per sweep point.

use gpml::spectral::EigenSystem;
use gpml::util::json::Json;
use gpml::util::rng::Rng;
use gpml::util::timing::{linear_fit, Stats};

/// The paper's sweep: N = 32 .. 8192 on a log2 scale.
pub const PAPER_SWEEP: [usize; 9] = [32, 64, 128, 256, 512, 1024, 2048, 4096, 8192];

/// Kernel-like eigensystem: geometrically decaying spectrum + unit-scale
/// projected targets.
pub fn synthetic_eigensystem(n: usize, seed: u64) -> EigenSystem {
    let mut rng = Rng::new(seed);
    let decay = 0.999f64;
    let s: Vec<f64> = (0..n)
        .map(|i| (n as f64) * decay.powi(i as i32) * rng.uniform_in(0.5, 1.0))
        .collect();
    let yt: Vec<f64> = rng.normal_vec(n);
    let yy = yt.iter().map(|v| v * v).sum();
    EigenSystem::from_parts(s, yt.iter().map(|v| v * v).collect(), n, yy)
}

/// Iterations for a rust-path measurement at size n (keeps total time
/// bounded while retaining enough samples at small n).
pub fn rust_iters(n: usize) -> usize {
    (2_000_000 / n).clamp(200, 20_000)
}

/// Iterations for a PJRT-path measurement (dispatch-dominated).
pub fn pjrt_iters(_n: usize) -> usize {
    300
}

/// Open the artifact runtime if present (benches degrade to rust-only).
pub fn open_runtime() -> Option<gpml::runtime::PjrtRuntime> {
    let dir = std::env::var_os("GPML_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    match gpml::runtime::PjrtRuntime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("(no PJRT artifacts: {e:#}; rust-only bench)");
            None
        }
    }
}

/// Print the tau(N) = a + b N fit next to the paper's reported fit.
pub fn print_fit(label: &str, ns: &[f64], us: &[f64], paper: &str) {
    let (a, b, r2) = gpml::util::timing::linear_fit(ns, us);
    println!("\nfit {label}: tau(N) = {a:.2} + {b:.5} N  [us]  (R^2 = {r2:.4})");
    println!("paper (MATLAB R2010a, Core2 Q9550): {paper}");
}

/// One measured series of a bench sweep: a label and per-N stats
/// (parallel to the sweep's `ns`).
pub struct Series<'a> {
    pub label: &'a str,
    pub stats: &'a [Stats],
}

/// JSON for one series: one `Stats::to_json` object per sweep point
/// (median/p10/p90/mean/min us and the sample count backing them) plus
/// the `tau(N) = a + b N` least-squares fit over the medians.
fn series_json(ns: &[usize], s: &Series) -> Json {
    let nsf: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
    let med: Vec<f64> = s.stats.iter().map(|st| st.median_us).collect();
    let (a, b, r2) = linear_fit(&nsf, &med);
    Json::obj(vec![
        ("per_n", Json::Arr(s.stats.iter().map(|st| st.to_json()).collect())),
        ("median_us", Json::arr_f64(&med)),
        (
            "fit",
            Json::obj(vec![
                ("a_us", Json::Num(a)),
                ("b_us_per_n", Json::Num(b)),
                ("r2", Json::Num(r2)),
            ]),
        ),
    ])
}

/// Machine-readable bench record: the N sweep, the pool width the bench
/// ran with, and every measured series with its linear fit.  Extra
/// bench-specific fields ride along via `extra`.
pub fn bench_json(bench: &str, ns: &[usize], series: &[Series], extra: Vec<(&str, Json)>) -> Json {
    let mut fields = vec![
        ("bench", Json::str(bench)),
        ("threads", Json::Num(gpml::util::threadpool::num_threads() as f64)),
        ("ns", Json::arr_f64(&ns.iter().map(|&n| n as f64).collect::<Vec<_>>())),
        (
            "series",
            Json::Obj(
                series
                    .iter()
                    .map(|s| (s.label.to_string(), series_json(ns, s)))
                    .collect(),
            ),
        ),
    ];
    fields.extend(extra);
    Json::obj(fields)
}

/// Write `BENCH_<name>.json` next to the stdout tables (the bench's
/// working directory — the workspace root under `cargo bench`) so the
/// perf trajectory is tracked across PRs.
pub fn write_bench_json(bench: &str, payload: &Json) {
    let path = format!("BENCH_{bench}.json");
    match std::fs::write(&path, format!("{payload}\n")) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

#![allow(dead_code)] // shared across multiple bench binaries; each uses a subset
//! Shared helpers for the paper-figure benches: synthetic eigensystems
//! with kernel-like decaying spectra, the N sweep, and output formatting.
//!
//! The figures time *per-iterate evaluation* given the eigendecomposition
//! (exactly what the paper's §3 measures: "the average execution time of
//! these quantities"), so the eigensystem here is synthesized directly —
//! a geometric spectrum matching what RBF Gram matrices produce — rather
//! than paying an O(N^3) decomposition per sweep point.

use gpml::spectral::EigenSystem;
use gpml::util::rng::Rng;

/// The paper's sweep: N = 32 .. 8192 on a log2 scale.
pub const PAPER_SWEEP: [usize; 9] = [32, 64, 128, 256, 512, 1024, 2048, 4096, 8192];

/// Kernel-like eigensystem: geometrically decaying spectrum + unit-scale
/// projected targets.
pub fn synthetic_eigensystem(n: usize, seed: u64) -> EigenSystem {
    let mut rng = Rng::new(seed);
    let decay = 0.999f64;
    let s: Vec<f64> = (0..n)
        .map(|i| (n as f64) * decay.powi(i as i32) * rng.uniform_in(0.5, 1.0))
        .collect();
    let yt: Vec<f64> = rng.normal_vec(n);
    let yy = yt.iter().map(|v| v * v).sum();
    EigenSystem::from_parts(s, yt.iter().map(|v| v * v).collect(), n, yy)
}

/// Iterations for a rust-path measurement at size n (keeps total time
/// bounded while retaining enough samples at small n).
pub fn rust_iters(n: usize) -> usize {
    (2_000_000 / n).clamp(200, 20_000)
}

/// Iterations for a PJRT-path measurement (dispatch-dominated).
pub fn pjrt_iters(_n: usize) -> usize {
    300
}

/// Open the artifact runtime if present (benches degrade to rust-only).
pub fn open_runtime() -> Option<gpml::runtime::PjrtRuntime> {
    let dir = std::env::var_os("GPML_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    match gpml::runtime::PjrtRuntime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("(no PJRT artifacts: {e:#}; rust-only bench)");
            None
        }
    }
}

/// Print the tau(N) = a + b N fit next to the paper's reported fit.
pub fn print_fit(label: &str, ns: &[f64], us: &[f64], paper: &str) {
    let (a, b, r2) = gpml::util::timing::linear_fit(ns, us);
    println!("\nfit {label}: tau(N) = {a:.2} + {b:.5} N  [us]  (R^2 = {r2:.4})");
    println!("paper (MATLAB R2010a, Core2 Q9550): {paper}");
}

//! Setup overhead: the one-time O(N^3) cost the paper amortizes —
//! `gram` (Gram construction) and `SymEigen::new` (eigendecomposition) —
//! timed separately across the sweep, serial (`threads = 1`) vs pooled
//! (the process default width), as the before/after evidence for the
//! scoped-pool substrate (DESIGN.md §6).
//!
//! Writes `BENCH_setup.json` next to the stdout table.
//!
//! Options (after `cargo bench --bench setup_overhead --`):
//!   --sizes 128,256,512,1024,2048   sweep override
//!   --max-n 512                     cap the sweep (CI smoke uses this)
//!   --iters 3                       timed repetitions per point

mod bench_common;

use bench_common::*;
use gpml::kernelfn::{gram, Kernel};
use gpml::linalg::{Matrix, SymEigen};
use gpml::util::cli::Args;
use gpml::util::json::Json;
use gpml::util::rng::Rng;
use gpml::util::threadpool;
use gpml::util::timing::{measure, Stats, Table};

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let default_sizes = [128usize, 256, 512, 1024, 2048];
    let mut sizes = args.get_usize_list("sizes", &default_sizes).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    match args.get_usize("max-n", usize::MAX) {
        Ok(cap) => sizes.retain(|&n| n <= cap),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
    if sizes.is_empty() {
        eprintln!("empty sweep after --sizes/--max-n filtering");
        std::process::exit(2);
    }
    let iters = args.get_usize("iters", 0).unwrap_or(0);

    let pooled = threadpool::num_threads();
    println!("== setup overhead: gram + SymEigen::new, serial vs pooled ({pooled} threads) ==");
    if pooled < 2 {
        println!("(pool width is 1 — set GPML_THREADS or run on a multi-core host for a contrast)");
    }

    let mut table = Table::new(&[
        "N",
        "gram 1T ms",
        "gram pooled ms",
        "eigen 1T ms",
        "eigen pooled ms",
        "setup speedup",
    ]);
    let (mut g1, mut gp, mut e1, mut ep): (Vec<Stats>, Vec<Stats>, Vec<Stats>, Vec<Stats>) =
        (vec![], vec![], vec![], vec![]);

    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let x = Matrix::from_fn(n, 4, |_, _| rng.normal());
        let reps = if iters > 0 {
            iters
        } else if n <= 512 {
            5
        } else if n <= 1024 {
            3
        } else {
            2
        };
        let kern = Kernel::Rbf { xi2: 1.5 };
        let k = gram(kern, &x);

        let st_g1 = threadpool::with_threads(1, || {
            measure(0, reps, || {
                std::hint::black_box(gram(kern, &x));
            })
        });
        let st_gp = measure(0, reps, || {
            std::hint::black_box(gram(kern, &x));
        });
        let st_e1 = threadpool::with_threads(1, || {
            measure(0, reps, || {
                std::hint::black_box(SymEigen::new(&k).expect("eigensolver"));
            })
        });
        let st_ep = measure(0, reps, || {
            std::hint::black_box(SymEigen::new(&k).expect("eigensolver"));
        });

        let setup_1t = st_g1.median_us + st_e1.median_us;
        let setup_p = st_gp.median_us + st_ep.median_us;
        table.row(&[
            n.to_string(),
            format!("{:.1}", st_g1.median_us / 1e3),
            format!("{:.1}", st_gp.median_us / 1e3),
            format!("{:.1}", st_e1.median_us / 1e3),
            format!("{:.1}", st_ep.median_us / 1e3),
            format!("{:.2}x", setup_1t / setup_p),
        ]);
        g1.push(st_g1);
        gp.push(st_gp);
        e1.push(st_e1);
        ep.push(st_ep);
    }
    table.print();

    let last = sizes.len() - 1;
    let gram_speedup = g1[last].median_us / gp[last].median_us;
    let eigen_speedup = e1[last].median_us / ep[last].median_us;
    let setup_speedup =
        (g1[last].median_us + e1[last].median_us) / (gp[last].median_us + ep[last].median_us);
    println!(
        "\n@ N={}: gram {gram_speedup:.2}x, eigen {eigen_speedup:.2}x, gram+eigen {setup_speedup:.2}x ({pooled} threads vs 1)",
        sizes[last]
    );

    let payload = bench_json(
        "setup",
        &sizes,
        &[
            Series { label: "gram_serial", stats: &g1 },
            Series { label: "gram_pooled", stats: &gp },
            Series { label: "eigen_serial", stats: &e1 },
            Series { label: "eigen_pooled", stats: &ep },
        ],
        vec![
            ("threads_pooled", Json::Num(pooled as f64)),
            (
                "speedup_at_max_n",
                Json::obj(vec![
                    ("n", Json::Num(sizes[last] as f64)),
                    ("gram", Json::Num(gram_speedup)),
                    ("eigen", Json::Num(eigen_speedup)),
                    ("setup", Json::Num(setup_speedup)),
                ]),
            ),
        ],
    );
    write_bench_json("setup", &payload);
}

//! Setup overhead: the one-time O(N^3) cost the paper amortizes —
//! `gram` (Gram construction), `matmul` (the GEMM shape the D&C
//! back-multiply and the sparse baselines lean on) and `SymEigen::new`
//! (eigendecomposition) — timed separately across the sweep, serial
//! (`threads = 1`) vs pooled (the process default width), as the
//! before/after evidence for the scoped-pool substrate (DESIGN.md §6).
//!
//! Since ISSUE 8 the eigendecomposition is timed under *both* solvers
//! (DESIGN.md §12): `eigen_ql_*` is the classic implicit-shift QL
//! sweep, `eigen_dac_*` the divide-and-conquer default.  The
//! `dac_vs_ql` ratio (QL pooled over D&C pooled at the largest N) is
//! the headline series, with an acceptance floor once the sweep
//! reaches N >= 512 on >= 4-way hardware.  ISSUE 10 adds a second
//! acceptance floor: on AVX2+FMA hardware the `GPML_KERNEL=simd`
//! microkernel backend must be >= 2x over `scalar` for the serial gram
//! and GEMM at N >= 1024 (DESIGN.md §14).  CI smoke runs stay below
//! both floors and only feed the bench-gate envelopes in
//! BENCH_setup.json.
//!
//! Writes `BENCH_setup.json` next to the stdout table.
//!
//! Options (after `cargo bench --bench setup_overhead --`):
//!   --sizes 128,256,512,1024,2048   sweep override
//!   --max-n 512                     cap the sweep (CI smoke uses this)
//!   --iters 3                       timed repetitions per point

mod bench_common;

use bench_common::*;
use gpml::kernelfn::{gram, Kernel};
use gpml::linalg::{
    default_kernel_backend, gemm, simd_available, with_kernel_backend, EigenSolver, KernelBackend,
    Matrix, SymEigen,
};
use gpml::util::cli::Args;
use gpml::util::json::Json;
use gpml::util::rng::Rng;
use gpml::util::threadpool;
use gpml::util::timing::{measure, Stats, Table};

fn main() {
    let args = Args::from_env().unwrap_or_default();
    let default_sizes = [128usize, 256, 512, 1024, 2048];
    let mut sizes = args.get_usize_list("sizes", &default_sizes).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    match args.get_usize("max-n", usize::MAX) {
        Ok(cap) => sizes.retain(|&n| n <= cap),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
    if sizes.is_empty() {
        eprintln!("empty sweep after --sizes/--max-n filtering");
        std::process::exit(2);
    }
    let iters = args.get_usize("iters", 0).unwrap_or(0);

    let pooled = threadpool::num_threads();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let kb = default_kernel_backend();
    println!(
        "== setup overhead: gram + gemm + SymEigen (ql vs dac), serial vs pooled \
         ({pooled} threads, {hw}-way hardware, kernel backend: {}) ==",
        kb.as_str()
    );
    if pooled < 2 {
        println!("(pool width is 1 — set GPML_THREADS or run on a multi-core host for a contrast)");
    }

    let mut table = Table::new(&[
        "N",
        "gram 1T ms",
        "gram pooled ms",
        "gemm 1T ms",
        "gemm pooled ms",
        "ql 1T ms",
        "ql pooled ms",
        "dac 1T ms",
        "dac pooled ms",
        "dac vs ql",
    ]);
    let mut g1: Vec<Stats> = vec![];
    let mut gp: Vec<Stats> = vec![];
    let mut ge1: Vec<Stats> = vec![];
    let mut gep: Vec<Stats> = vec![];
    let mut ql1: Vec<Stats> = vec![];
    let mut qlp: Vec<Stats> = vec![];
    let mut dac1: Vec<Stats> = vec![];
    let mut dacp: Vec<Stats> = vec![];

    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let x = Matrix::from_fn(n, 4, |_, _| rng.normal());
        let reps = if iters > 0 {
            iters
        } else if n <= 512 {
            5
        } else if n <= 1024 {
            3
        } else {
            2
        };
        let kern = Kernel::Rbf { xi2: 1.5 };
        let k = gram(kern, &x);

        let st_g1 = threadpool::with_threads(1, || {
            measure(0, reps, || {
                std::hint::black_box(gram(kern, &x));
            })
        });
        let st_gp = measure(0, reps, || {
            std::hint::black_box(gram(kern, &x));
        });
        let st_ge1 = threadpool::with_threads(1, || {
            measure(0, reps, || {
                std::hint::black_box(gemm::matmul(&k, &k));
            })
        });
        let st_gep = measure(0, reps, || {
            std::hint::black_box(gemm::matmul(&k, &k));
        });
        let st_ql1 = threadpool::with_threads(1, || {
            measure(0, reps, || {
                std::hint::black_box(SymEigen::new_with(&k, EigenSolver::Ql).expect("ql"));
            })
        });
        let st_qlp = measure(0, reps, || {
            std::hint::black_box(SymEigen::new_with(&k, EigenSolver::Ql).expect("ql"));
        });
        let st_dac1 = threadpool::with_threads(1, || {
            measure(0, reps, || {
                std::hint::black_box(SymEigen::new_with(&k, EigenSolver::Dac).expect("dac"));
            })
        });
        let st_dacp = measure(0, reps, || {
            std::hint::black_box(SymEigen::new_with(&k, EigenSolver::Dac).expect("dac"));
        });

        table.row(&[
            n.to_string(),
            format!("{:.1}", st_g1.median_us / 1e3),
            format!("{:.1}", st_gp.median_us / 1e3),
            format!("{:.1}", st_ge1.median_us / 1e3),
            format!("{:.1}", st_gep.median_us / 1e3),
            format!("{:.1}", st_ql1.median_us / 1e3),
            format!("{:.1}", st_qlp.median_us / 1e3),
            format!("{:.1}", st_dac1.median_us / 1e3),
            format!("{:.1}", st_dacp.median_us / 1e3),
            format!("{:.2}x", st_qlp.median_us / st_dacp.median_us),
        ]);
        g1.push(st_g1);
        gp.push(st_gp);
        ge1.push(st_ge1);
        gep.push(st_gep);
        ql1.push(st_ql1);
        qlp.push(st_qlp);
        dac1.push(st_dac1);
        dacp.push(st_dacp);
    }
    table.print();

    let last = sizes.len() - 1;
    let gram_speedup = g1[last].median_us / gp[last].median_us;
    let gemm_speedup = ge1[last].median_us / gep[last].median_us;
    let eigen_speedup = dac1[last].median_us / dacp[last].median_us;
    let dac_over_ql = qlp[last].median_us / dacp[last].median_us;
    let setup_speedup = (g1[last].median_us + dac1[last].median_us)
        / (gp[last].median_us + dacp[last].median_us);
    println!(
        "\n@ N={}: gram {gram_speedup:.2}x, gemm {gemm_speedup:.2}x, eigen(dac) \
         {eigen_speedup:.2}x, gram+eigen {setup_speedup:.2}x ({pooled} threads vs 1); \
         dac over ql {dac_over_ql:.2}x (acceptance floor at N>=512: dac beats ql)",
        sizes[last]
    );

    // Acceptance (ISSUE 8): at full scale the D&C default must beat the
    // QL escape hatch.  Skipped on CI smoke sweeps (--max-n 256) and on
    // narrow hardware, matching the theta_sweep gate pattern.
    if sizes[last] >= 512 && hw >= 4 {
        assert!(
            dac_over_ql >= 1.1,
            "acceptance failed: D&C eigensolver only {dac_over_ql:.2}x vs QL at N={} \
             (pooled); expected the GEMM-dominated merge to win at this size",
            sizes[last]
        );
    }

    // Scalar-vs-simd contrast at the largest N (ISSUE 10): serial gram
    // and GEMM under each pinned microkernel backend.  Off AVX2+FMA both
    // pins resolve to the scalar path and the ratio prints as ~1x.
    let nmax = sizes[last];
    let mut rng = Rng::new(nmax as u64);
    let x = Matrix::from_fn(nmax, 4, |_, _| rng.normal());
    let kern = Kernel::Rbf { xi2: 1.5 };
    let k = gram(kern, &x);
    let contrast_reps = if iters > 0 { iters } else { 2 };
    let timed = |backend: KernelBackend, f: &dyn Fn()| {
        threadpool::with_threads(1, || {
            with_kernel_backend(backend, || measure(0, contrast_reps, f))
        })
    };
    let gram_scalar = timed(KernelBackend::Scalar, &|| {
        std::hint::black_box(gram(kern, &x));
    });
    let gram_simd = timed(KernelBackend::Simd, &|| {
        std::hint::black_box(gram(kern, &x));
    });
    let gemm_scalar = timed(KernelBackend::Scalar, &|| {
        std::hint::black_box(gemm::matmul(&k, &k));
    });
    let gemm_simd = timed(KernelBackend::Simd, &|| {
        std::hint::black_box(gemm::matmul(&k, &k));
    });
    let gram_simd_speedup = gram_scalar.median_us / gram_simd.median_us;
    let gemm_simd_speedup = gemm_scalar.median_us / gemm_simd.median_us;
    println!(
        "simd vs scalar @ N={nmax} (serial): gram {gram_simd_speedup:.2}x, gemm \
         {gemm_simd_speedup:.2}x (avx2+fma detected: {})",
        simd_available()
    );

    // Acceptance (ISSUE 10): the vector backend must be >= 2x over the
    // scalar backend for both GEMM-shaped kernels at N >= 1024 on
    // hardware that can actually run it.
    if simd_available() && nmax >= 1024 {
        assert!(
            gram_simd_speedup >= 2.0,
            "acceptance failed: simd gram only {gram_simd_speedup:.2}x vs scalar at N={nmax}"
        );
        assert!(
            gemm_simd_speedup >= 2.0,
            "acceptance failed: simd gemm only {gemm_simd_speedup:.2}x vs scalar at N={nmax}"
        );
    }

    let payload = bench_json(
        "setup",
        &sizes,
        &[
            Series { label: "gram_serial", stats: &g1 },
            Series { label: "gram_pooled", stats: &gp },
            Series { label: "gemm_serial", stats: &ge1 },
            Series { label: "gemm_pooled", stats: &gep },
            Series { label: "eigen_ql_serial", stats: &ql1 },
            Series { label: "eigen_ql_pooled", stats: &qlp },
            Series { label: "eigen_dac_serial", stats: &dac1 },
            Series { label: "eigen_dac_pooled", stats: &dacp },
        ],
        vec![
            ("threads_pooled", Json::Num(pooled as f64)),
            ("kernel_backend", Json::str(kb.as_str())),
            ("simd_available", Json::Bool(simd_available())),
            (
                "speedup_at_max_n",
                Json::obj(vec![
                    ("n", Json::Num(sizes[last] as f64)),
                    ("gram", Json::Num(gram_speedup)),
                    ("gemm", Json::Num(gemm_speedup)),
                    ("eigen", Json::Num(eigen_speedup)),
                    ("setup", Json::Num(setup_speedup)),
                ]),
            ),
            (
                "dac_vs_ql_at_max_n",
                Json::obj(vec![
                    ("n", Json::Num(sizes[last] as f64)),
                    ("ql_over_dac_pooled", Json::Num(dac_over_ql)),
                ]),
            ),
            (
                "simd_vs_scalar_at_max_n",
                Json::obj(vec![
                    ("n", Json::Num(nmax as f64)),
                    ("gram_serial", Json::Num(gram_simd_speedup)),
                    ("gemm_serial", Json::Num(gemm_simd_speedup)),
                ]),
            ),
        ],
    );
    write_bench_json("setup", &payload);
}

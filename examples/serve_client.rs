//! Serving demo: start the coordinator server in-process and walk the
//! session workflow — create a session (the one-time O(N^3) setup),
//! run warm tunes / evaluations / predictions against it in O(N),
//! contrast with a cold inline tune, and print the cache statistics.
//!
//! Run: `cargo run --release --example serve_client`

use gpml::coordinator::client::Client;
use gpml::coordinator::protocol::{EvaluateRequest, PredictRequest};
use gpml::coordinator::server::Server;
use gpml::coordinator::session::SessionTuneRequest;
use gpml::coordinator::{Coordinator, GlobalStrategy, ObjectiveKind, TuneRequest};
use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::linalg::Matrix;
use gpml::spectral::HyperParams;
use gpml::util::json::Json;

fn main() -> anyhow::Result<()> {
    println!("== coordinator serving demo ==");
    // ephemeral port; pure-rust jobs run on the worker pool, PJRT jobs
    // (if artifacts exist) on the serial coordinator worker
    let server = Server::start("127.0.0.1:0", Coordinator::auto)?;
    println!("server listening on {} ({} pool workers)", server.addr, server.workers());

    let mut client = Client::connect(&server.addr.to_string())?;
    println!("ping -> {}", client.ping()?);

    // --- session workflow: pay the setup once, serve O(N) forever ---
    let spec =
        SyntheticSpec { n: 128, p: 4, sigma2: 0.1, lambda2: 1.0, seed: 3, ..Default::default() };
    let ds = synthetic(spec, 1);
    let kernel = Kernel::Rbf { xi2: 2.0 };

    let created = client.create_session_full(&ds.x, kernel, 0)?;
    let id = created.get("session_id").and_then(Json::as_f64).unwrap() as u64;
    println!(
        "\ncreate_session: id={id} cached={} setup={:.3}s ({} bytes pinned)",
        created.get("cached").and_then(Json::as_bool).unwrap_or(false),
        created.get("gram_seconds").and_then(Json::as_f64).unwrap_or(0.0)
            + created.get("eigen_seconds").and_then(Json::as_f64).unwrap_or(0.0),
        created.get("bytes").and_then(Json::as_f64).unwrap_or(0.0),
    );

    // warm tunes: zero gram/eigen work on the server
    let mut sreq = SessionTuneRequest::new(id, ds.ys.clone());
    sreq.strategy = GlobalStrategy::Pso { particles: 32, iterations: 15 };
    sreq.objective = ObjectiveKind::Evidence;
    for round in 1..=2 {
        let res = client.tune_session(&sreq)?;
        print_result(&format!("warm tune #{round} (session {id})"), &res);
    }

    // O(N) score/Jacobian/Hessian at a point (e.g. for an external optimizer)
    let ev = client.evaluate(&EvaluateRequest {
        session_id: id,
        y: ds.ys[0].clone(),
        hp: HyperParams::new(0.1, 1.0),
        objective: ObjectiveKind::Evidence,
    })?;
    println!(
        "\nevaluate @ (0.1, 1.0): score={:.4} jac={}",
        ev.get("score").and_then(Json::as_f64).unwrap_or(f64::NAN),
        ev.get("jac").unwrap(),
    );

    // posterior prediction at new inputs, with Prop. 2.4 variances
    let xnew = Matrix::from_fn(3, 4, |i, j| (i as f64 - 1.0) * 0.3 + j as f64 * 0.1);
    let pr = client.predict(&PredictRequest {
        session_id: id,
        y: ds.ys[0].clone(),
        xnew,
        hp: HyperParams::new(0.1, 1.0),
    })?;
    println!("predict: mean={} var={}", pr.get("mean").unwrap(), pr.get("var").unwrap());

    // --- contrast: a cold inline tune of a *different* dataset ---
    let ds2 = synthetic(SyntheticSpec { seed: 99, ..spec }, 3);
    let mut req = TuneRequest::new(ds2.x, ds2.ys, kernel);
    req.strategy = GlobalStrategy::Grid { points_per_axis: 9 };
    let mut client2 = Client::connect(&server.addr.to_string())?;
    let res = client2.tune(&req)?;
    print_result("cold inline tune (3 outputs, new connection)", &res);

    let stats = client.stats()?;
    println!(
        "\ncache stats: sessions={} setups={} hits={} misses={} evictions={} ({} bytes)",
        stats.get("sessions").and_then(Json::as_f64).unwrap_or(-1.0),
        stats.get("setups").and_then(Json::as_f64).unwrap_or(-1.0),
        stats.get("hits").and_then(Json::as_f64).unwrap_or(-1.0),
        stats.get("misses").and_then(Json::as_f64).unwrap_or(-1.0),
        stats.get("evictions").and_then(Json::as_f64).unwrap_or(-1.0),
        stats.get("bytes").and_then(Json::as_f64).unwrap_or(-1.0),
    );

    client.drop_session(id)?;
    server.stop();
    println!("server stopped; demo OK");
    Ok(())
}

fn print_result(label: &str, res: &Json) {
    let cached = res.get("eigen_cached").and_then(Json::as_bool).unwrap_or(false);
    let tune_s = res.get("tune_seconds").and_then(Json::as_f64).unwrap_or(f64::NAN);
    let eigen_s = res.get("eigen_seconds").and_then(Json::as_f64).unwrap_or(f64::NAN);
    println!("\n{label}:");
    println!("  eigen_cached={cached} eigen={eigen_s:.3}s tune={tune_s:.3}s");
    if let Some(outs) = res.get("outputs").and_then(Json::as_arr) {
        for (i, o) in outs.iter().enumerate() {
            println!(
                "  y{i}: sigma2={:.4e} lambda2={:.4e} score={:.4}",
                o.get("sigma2").and_then(Json::as_f64).unwrap_or(f64::NAN),
                o.get("lambda2").and_then(Json::as_f64).unwrap_or(f64::NAN),
                o.get("score").and_then(Json::as_f64).unwrap_or(f64::NAN),
            );
        }
    }
}

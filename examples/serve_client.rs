//! Serving demo: start the coordinator server in-process, submit tuning
//! jobs from several client connections (including a repeated job that
//! hits the eigen-cache and a multi-output job), and print the responses.
//!
//! Run: `cargo run --release --example serve_client`

use gpml::coordinator::client::Client;
use gpml::coordinator::server::Server;
use gpml::coordinator::{Coordinator, GlobalStrategy, ObjectiveKind, TuneRequest};
use gpml::data::{synthetic, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::util::json::Json;

fn main() -> anyhow::Result<()> {
    println!("== coordinator serving demo ==");
    // ephemeral port; the worker thread owns the (non-Send) coordinator
    let server = Server::start("127.0.0.1:0", Coordinator::auto)?;
    println!("server listening on {}", server.addr);

    let mut client = Client::connect(&server.addr.to_string())?;
    println!("ping -> {}", client.ping()?);

    // job 1: single output
    let spec = SyntheticSpec { n: 128, p: 4, sigma2: 0.1, lambda2: 1.0, seed: 3, ..Default::default() };
    let ds = synthetic(spec, 1);
    let mut req = TuneRequest::new(ds.x.clone(), ds.ys.clone(), Kernel::Rbf { xi2: 2.0 });
    req.strategy = GlobalStrategy::Pso { particles: 32, iterations: 15 };
    req.objective = ObjectiveKind::Evidence;
    let res = client.tune(&req)?;
    print_result("job 1 (fresh dataset)", &res);

    // job 2: identical dataset -> eigen-cache hit on the server
    let res2 = client.tune(&req)?;
    print_result("job 2 (same dataset, cache hit expected)", &res2);

    // job 3: multi-output over a second connection
    let ds3 = synthetic(spec, 3);
    let mut req3 = TuneRequest::new(ds3.x, ds3.ys, Kernel::Rbf { xi2: 2.0 });
    req3.strategy = GlobalStrategy::Grid { points_per_axis: 9 };
    let mut client2 = Client::connect(&server.addr.to_string())?;
    let res3 = client2.tune(&req3)?;
    print_result("job 3 (3 outputs, new connection)", &res3);

    let info = client.info()?;
    println!(
        "\nserver info: pjrt={} cache_hits={} cache_misses={}",
        info.get("pjrt").and_then(Json::as_bool).unwrap_or(false),
        info.get("cache_hits").and_then(Json::as_f64).unwrap_or(-1.0),
        info.get("cache_misses").and_then(Json::as_f64).unwrap_or(-1.0),
    );

    server.stop();
    println!("server stopped; demo OK");
    Ok(())
}

fn print_result(label: &str, res: &Json) {
    let cached = res.get("eigen_cached").and_then(Json::as_bool).unwrap_or(false);
    let tune_s = res.get("tune_seconds").and_then(Json::as_f64).unwrap_or(f64::NAN);
    let eigen_s = res.get("eigen_seconds").and_then(Json::as_f64).unwrap_or(f64::NAN);
    println!("\n{label}:");
    println!("  eigen_cached={cached} eigen={eigen_s:.3}s tune={tune_s:.3}s");
    if let Some(outs) = res.get("outputs").and_then(Json::as_arr) {
        for (i, o) in outs.iter().enumerate() {
            println!(
                "  y{i}: sigma2={:.4e} lambda2={:.4e} score={:.4}",
                o.get("sigma2").and_then(Json::as_f64).unwrap_or(f64::NAN),
                o.get("lambda2").and_then(Json::as_f64).unwrap_or(f64::NAN),
                o.get("score").and_then(Json::as_f64).unwrap_or(f64::NAN),
            );
        }
    }
}

//! The paper's headline scenario (§3, eq. 44-45): a dataset size where
//! naive O(N^3)-per-iterate tuning is impractical becomes interactive.
//!
//! For N=4096 (default) this runs the full pipeline and then reports the
//! measured per-iteration cost of the spectral path next to the *measured*
//! cost of a single naive evaluation — the paper's "would normally be
//! considered intractable" comparison, with the naive side extrapolated to
//! the same number of iterations instead of run to completion.
//!
//! Run: `cargo run --release --example large_scale [-- --n 4096]`

use std::time::Instant;

use gpml::data::{self, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::naive::NaiveEvaluator;
use gpml::optim::{self, Bounds, Objective, PsoOptions};
use gpml::spectral::{HyperParams, SpectralGp};
use gpml::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 4096).map_err(anyhow::Error::msg)?;
    let naive_n = args.get_usize("naive-n", n.min(1024)).map_err(anyhow::Error::msg)?;

    let spec = SyntheticSpec {
        n,
        p: 8,
        kernel: Kernel::Rbf { xi2: 2.0 },
        sigma2: 0.05,
        lambda2: 1.0,
        seed: 123,
    };
    println!("== large-scale tuning: N={n} ==");
    let t_data = Instant::now();
    let ds = data::synthetic(spec, 1);
    println!("data generation      : {:.1} s", t_data.elapsed().as_secs_f64());

    // one-time O(N^3) overhead
    let t_fit = Instant::now();
    let gp = SpectralGp::fit(spec.kernel, ds.x.clone()).map_err(|e| anyhow::anyhow!("{e}"))?;
    let fit_s = t_fit.elapsed().as_secs_f64();
    println!("gram + eigendecomp   : {fit_s:.1} s   (one-time O(N^3) overhead)");

    let mut es = gp.eigensystem(ds.y());

    // global + local tuning, all O(N) per iterate
    let t_tune = Instant::now();
    let global = optim::pso_search(
        &mut es,
        Bounds::default(),
        PsoOptions { particles: 64, iterations: 25, ..Default::default() },
    );
    let refined = optim::newton_refine(&mut es, global.hp, Bounds::default(), Default::default());
    let tune_s = t_tune.elapsed().as_secs_f64();
    let k_star = global.evals + refined.evals;
    println!(
        "tuning (k*={k_star})    : {tune_s:.3} s  ->  {:.1} us per O(N) evaluation",
        tune_s * 1e6 / k_star as f64
    );
    println!(
        "result: sigma2={:.4e} lambda2={:.4e} score={:.4}",
        refined.hp.sigma2, refined.hp.lambda2, refined.score
    );

    // measured naive per-iteration cost at naive_n, extrapolated to N
    println!("\n-- naive O(N^3) comparison --");
    let sub_x = gpml::linalg::Matrix::from_fn(naive_n, ds.p(), |i, j| ds.x[(i, j)]);
    let sub_y = ds.y()[..naive_n].to_vec();
    let k_sub = gpml::kernelfn::gram(spec.kernel, &sub_x);
    let naive = NaiveEvaluator::new(k_sub, sub_y);
    let t_naive = Instant::now();
    let _ = naive.score(HyperParams::new(refined.hp.sigma2, refined.hp.lambda2));
    let naive_one = t_naive.elapsed().as_secs_f64();
    let scale = (n as f64 / naive_n as f64).powi(3);
    let naive_full = naive_one * scale * k_star as f64;
    println!("one naive evaluation at N={naive_n}: {naive_one:.2} s (measured)");
    println!(
        "extrapolated naive tuning at N={n}: {naive_one:.2} s x {scale:.0} (N^3 scaling) x {k_star} iters = {:.1} hours",
        naive_full / 3600.0
    );
    println!(
        "spectral total (overhead + tuning): {:.1} s  ->  speed-up ~{:.0}x",
        fit_s + tune_s,
        naive_full / (fit_s + tune_s)
    );
    println!("\nlarge_scale OK");
    Ok(())
}

//! Multi-output tuning — paper §2.1: "in the case of multiple-output
//! training datasets the eigendecomposition need only be computed once".
//!
//! Tunes M outputs over one shared decomposition and compares against the
//! cost of M independent decompositions (what a per-output pipeline would
//! pay).
//!
//! Run: `cargo run --release --example multi_output [-- --n 512 --outputs 8]`

use std::time::Instant;

use gpml::coordinator::{Coordinator, GlobalStrategy, ObjectiveKind, TuneRequest};
use gpml::data::{self, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::linalg::SymEigen;
use gpml::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 512).map_err(anyhow::Error::msg)?;
    let m = args.get_usize("outputs", 8).map_err(anyhow::Error::msg)?;

    let spec = SyntheticSpec {
        n,
        p: 6,
        kernel: Kernel::Rbf { xi2: 2.0 },
        sigma2: 0.1,
        lambda2: 1.0,
        seed: 7,
    };
    println!("== multi-output tuning: N={n}, M={m} outputs ==");
    let ds = data::synthetic(spec, m);

    // --- shared decomposition through the coordinator ---
    let mut coord = Coordinator::auto();
    println!("backend: {}", if coord.has_runtime() { "PJRT artifacts" } else { "pure rust" });
    let mut req = TuneRequest::new(ds.x.clone(), ds.ys.clone(), spec.kernel);
    req.strategy = GlobalStrategy::Pso { particles: 64, iterations: 15 };
    req.objective = ObjectiveKind::Evidence;
    let t0 = Instant::now();
    let res = coord.tune(&req)?;
    let shared_total = t0.elapsed().as_secs_f64();

    println!("\nshared-decomposition pipeline:");
    println!("  gram+eigen overhead : {:.3} s (paid once)", res.gram_seconds + res.eigen_seconds);
    println!("  tuning ({m} outputs)  : {:.3} s", res.tune_seconds);
    println!("  total               : {shared_total:.3} s");
    for (i, o) in res.outputs.iter().enumerate() {
        println!(
            "    y{i}: sigma2={:.4e} lambda2={:.4e} (global {} evals)",
            o.hp.sigma2, o.hp.lambda2, o.global_evals
        );
    }

    // --- what M independent decompositions would cost ---
    let k = gpml::kernelfn::gram(spec.kernel, &ds.x);
    let t1 = Instant::now();
    let _ = SymEigen::new(&k).unwrap();
    let one_eigen = t1.elapsed().as_secs_f64();
    println!("\nper-output pipeline estimate:");
    println!("  one eigendecomposition: {one_eigen:.3} s");
    println!(
        "  M = {m} decompositions : {:.3} s (vs {:.3} s paid above)",
        one_eigen * m as f64,
        res.gram_seconds + res.eigen_seconds
    );
    println!(
        "  multi-output saving   : {:.1}x on the O(N^3) stage",
        (one_eigen * m as f64) / (res.eigen_seconds + res.gram_seconds).max(1e-9)
    );
    Ok(())
}

//! Algorithm 1 (paper §2.2): two-step tuning of the RBF bandwidth xi2
//! together with (sigma2, lambda2).
//!
//! The outer golden-section line search moves xi2 — each move pays a fresh
//! O(N^3) Gram + eigendecomposition — while the inner loop tunes
//! (sigma2, lambda2) at O(N) per iterate.  The example reports how the
//! cost splits between the two loops, which is the entire point of the
//! algorithm.
//!
//! Run: `cargo run --release --example kernel_tuning [-- --n 384]`

use std::time::Instant;

use gpml::data::{self, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::optim::{two_step_tune, EvidenceObjective, TwoStepOptions};
use gpml::spectral::SpectralGp;
use gpml::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 384).map_err(anyhow::Error::msg)?;
    let true_xi2 = args.get_f64("xi2", 2.0).map_err(anyhow::Error::msg)?;

    let spec = SyntheticSpec {
        n,
        p: 4,
        kernel: Kernel::Rbf { xi2: true_xi2 },
        sigma2: 0.05,
        lambda2: 1.0,
        seed: 11,
    };
    println!("== Algorithm 1: kernel hyperparameter tuning ==");
    println!("data: N={n} P={} generated with xi2={true_xi2}, sigma2={}, lambda2={}",
             spec.p, spec.sigma2, spec.lambda2);
    let ds = data::synthetic(spec, 1);
    let y = ds.y().to_vec();
    let x = ds.x;

    let mut outer_secs = Vec::new();
    let t0 = Instant::now();
    let result = two_step_tune(
        |theta| {
            let t = Instant::now();
            let gp = SpectralGp::fit(Kernel::Rbf { xi2: theta }, x.clone())
                .expect("eigensolver convergence");
            let es = gp.eigensystem(&y);
            outer_secs.push(t.elapsed().as_secs_f64());
            // evidence inner objective: interior optimum (see DESIGN.md on
            // the eq. 19 boundary pathology)
            EvidenceObjective(es)
        },
        TwoStepOptions {
            theta_range: (0.05, 50.0),
            outer_iters: 14,
            inner_grid: 9,
            ..Default::default()
        },
    );
    let total = t0.elapsed().as_secs_f64();
    let overhead: f64 = outer_secs.iter().sum();

    println!("\nresult:");
    println!("  xi2     = {:.4}   (generating value {true_xi2})", result.theta);
    println!("  sigma2  = {:.5e} (generating value {})", result.hp.sigma2, spec.sigma2);
    println!("  lambda2 = {:.5e} (generating value {})", result.hp.lambda2, spec.lambda2);
    println!("  score   = {:.5}", result.score);
    println!("\ncost split (the point of Algorithm 1):");
    println!(
        "  outer loop: {} O(N^3) eigendecompositions = {:.3} s ({:.1}% of total)",
        result.outer_evals,
        overhead,
        100.0 * overhead / total
    );
    println!(
        "  inner loop: {} O(N) evaluations           = {:.3} s",
        result.inner_evals,
        total - overhead
    );
    println!(
        "  per inner evaluation: {:.1} us",
        (total - overhead) * 1e6 / result.inner_evals.max(1) as f64
    );
    println!("  total: {total:.3} s");

    // sanity: the recovered bandwidth should be within a factor ~3 of truth
    let ratio = result.theta / true_xi2;
    if !(0.33..=3.0).contains(&ratio) {
        println!("warning: recovered xi2 off by {ratio:.2}x (small-N noise)");
    }
    Ok(())
}

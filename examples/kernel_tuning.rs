//! Algorithm 1 (paper §2.2) through the theta-plane tuning engine:
//! tune the RBF bandwidth xi2 together with (sigma2, lambda2) against a
//! session-backed eigen-family cache (DESIGN.md §9).
//!
//! The outer stage sweeps theta as **parallel bracketing wavefronts** —
//! each candidate's O(N^3) Gram + eigendecomposition runs concurrently
//! on the thread pool — and every setup lands in the session's family
//! cache, so the second sweep below is *warm*: zero eigendecompositions,
//! bitwise-identical result.  A serial golden-section sweep runs last
//! for comparison (it is warm too: its probes largely alias into the
//! cached wavefront thetas or rebuild only the few it needs).
//!
//! Run: `cargo run --release --example kernel_tuning [-- --n 384 --threads 4]`

use std::time::Instant;

use gpml::coordinator::session::{tune_theta, SessionStore, ThetaTuneRequest};
use gpml::data::{self, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::optim::ThetaSearch;
use gpml::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 384).map_err(anyhow::Error::msg)?;
    let true_xi2 = args.get_f64("xi2", 2.0).map_err(anyhow::Error::msg)?;
    gpml::util::threadpool::set_threads(args.get_usize("threads", 0).map_err(anyhow::Error::msg)?);

    let spec = SyntheticSpec {
        n,
        p: 4,
        kernel: Kernel::Rbf { xi2: true_xi2 },
        sigma2: 0.05,
        lambda2: 1.0,
        seed: 11,
    };
    println!("== Algorithm 1 via the theta-plane engine ==");
    println!(
        "data: N={n} P={} generated with xi2={true_xi2}, sigma2={}, lambda2={}",
        spec.p, spec.sigma2, spec.lambda2
    );
    let ds = data::synthetic(spec, 1);

    // the session holds the dataset; every theta probe is a family-cache
    // entry keyed off it (unbounded budget: this demo asserts the warm
    // re-sweep builds nothing, which a byte cap could defeat at large --n)
    let store = SessionStore::new(8, usize::MAX);
    let (sess, _) = store.create(spec.kernel, ds.x.clone())?;
    let mut req = ThetaTuneRequest::new(sess.id, ds.ys.clone());
    req.theta_range = (0.05, 50.0);
    req.outer_iters = 24;
    req.inner_grid = 9;
    req.search = ThetaSearch::Wavefront { width: 0 };
    req.objective = gpml::coordinator::ObjectiveKind::Evidence;

    let t0 = Instant::now();
    let cold = tune_theta(&store, &req)?;
    let cold_secs = t0.elapsed().as_secs_f64();
    let best = &cold.outputs[0];

    println!("\ncold wavefront sweep ({} threads):", gpml::util::threadpool::num_threads());
    println!("  xi2     = {:.4}   (generating value {true_xi2})", best.theta);
    println!("  sigma2  = {:.5e} (generating value {})", best.hp.sigma2, spec.sigma2);
    println!("  lambda2 = {:.5e} (generating value {})", best.hp.lambda2, spec.lambda2);
    println!("  score   = {:.5}", best.score);
    println!(
        "  cost: {} O(N^3) setups built over {} distinct thetas, {} inner evals, {cold_secs:.3} s",
        best.outer_evals, best.distinct_thetas, best.inner_evals
    );

    // same request again: the family is warm — zero setups, identical bits
    let t1 = Instant::now();
    let warm = tune_theta(&store, &req)?;
    let warm_secs = t1.elapsed().as_secs_f64();
    let wbest = &warm.outputs[0];
    assert_eq!(warm.setups_built, 0, "warm sweep must build nothing");
    assert_eq!(wbest.theta.to_bits(), best.theta.to_bits());
    assert_eq!(wbest.score.to_bits(), best.score.to_bits());
    println!("\nwarm re-sweep: 0 setups, bitwise-identical result, {warm_secs:.3} s");
    if warm_secs > 0.0 {
        println!("  cold/warm = {:.1}x", cold_secs / warm_secs);
    }

    // serial golden-section over the same (now mostly warm) family
    let mut golden_req = req.clone();
    golden_req.search = ThetaSearch::Golden;
    let t2 = Instant::now();
    let golden = tune_theta(&store, &golden_req)?;
    let gbest = &golden.outputs[0];
    println!(
        "\ngolden-section comparison: score {:.5} (wavefront {:.5}), {} fresh setups, {:.3} s",
        gbest.score,
        best.score,
        golden.setups_built,
        t2.elapsed().as_secs_f64()
    );

    let stats = store.stats();
    println!(
        "\nfamily cache: {} entries, {} hits / {} misses / {} evictions, {} total setups",
        stats.theta_entries, stats.theta_hits, stats.theta_misses, stats.theta_evictions,
        stats.setups
    );

    // sanity: the recovered bandwidth should be within a factor ~3 of truth
    let ratio = best.theta / true_xi2;
    if !(0.33..=3.0).contains(&ratio) {
        println!("warning: recovered xi2 off by {ratio:.2}x (small-N noise)");
    }
    Ok(())
}

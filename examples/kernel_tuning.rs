//! Algorithm 1 (paper §2.2) through the vector-theta tuning engine:
//! tune a 2-D ARD RBF's per-dimension lengthscales together with
//! (sigma2, lambda2) against a session-backed eigen-family cache
//! (DESIGN.md §9–§10).
//!
//! The outer stage runs **coordinate descent over parallel bracketing
//! wavefronts** — one axis at a time, each wave's O(N^3) Gram +
//! eigendecomposition concurrent on the thread pool — and the winning
//! candidate's (sigma2, lambda2) is polished by the exact-Hessian
//! Newton inner loop (O(N) per step).  Every setup lands in the
//! session's family cache keyed by the quantized theta *vector*, so the
//! second sweep below is *warm*: zero eigendecompositions,
//! bitwise-identical result.
//!
//! Run: `cargo run --release --example kernel_tuning [-- --n 384 --threads 4]`

use std::time::Instant;

use gpml::coordinator::session::{tune_theta, SessionStore, ThetaTuneRequest};
use gpml::data::{self, SyntheticSpec};
use gpml::kernelfn::{Kernel, ThetaVec};
use gpml::optim::{RefineKind, ThetaSearch};
use gpml::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 384).map_err(anyhow::Error::msg)?;
    gpml::util::threadpool::set_threads(args.get_usize("threads", 0).map_err(anyhow::Error::msg)?);

    // anisotropic ground truth: the second feature varies 4x faster
    let true_xi2 = [2.0f64, 0.5];
    let kernel = Kernel::RbfArd { xi2: ThetaVec::from_slice(&true_xi2).unwrap() };
    let spec = SyntheticSpec { n, p: 2, kernel, sigma2: 0.05, lambda2: 1.0, seed: 11 };
    println!("== Algorithm 1 via the vector-theta engine (2-D ARD) ==");
    println!(
        "data: N={n} P={} generated with xi2=({}, {}), sigma2={}, lambda2={}",
        spec.p, true_xi2[0], true_xi2[1], spec.sigma2, spec.lambda2
    );
    let ds = data::synthetic(spec, 1);

    // the session holds the dataset; every theta-vector probe is a
    // family-cache entry keyed off it (unbounded budget: this demo
    // asserts the warm re-sweep builds nothing, which a byte cap could
    // defeat at large --n)
    let store = SessionStore::new(8, usize::MAX);
    let (sess, _) = store.create(kernel, ds.x.clone())?;
    let mut req = ThetaTuneRequest::new(sess.id, ds.ys.clone());
    req.theta_ranges = vec![(0.05, 50.0), (0.05, 50.0)];
    req.outer_iters = 24;
    req.inner_grid = 9;
    req.search = ThetaSearch::Wavefront { width: 0 };
    req.objective = gpml::coordinator::ObjectiveKind::Evidence;

    let t0 = Instant::now();
    let cold = tune_theta(&store, &req)?;
    let cold_secs = t0.elapsed().as_secs_f64();
    let best = &cold.outputs[0];

    println!("\ncold coordinate-descent sweep ({} threads):", gpml::util::threadpool::num_threads());
    println!(
        "  xi2     = ({:.4}, {:.4})   (generating values {}, {})",
        best.theta.get(0),
        best.theta.get(1),
        true_xi2[0],
        true_xi2[1]
    );
    println!("  sigma2  = {:.5e} (generating value {})", best.hp.sigma2, spec.sigma2);
    println!("  lambda2 = {:.5e} (generating value {})", best.hp.lambda2, spec.lambda2);
    println!("  score   = {:.5}", best.score);
    println!(
        "  cost: {} O(N^3) setups built over {} distinct theta vectors, {} inner evals, \
         {} Newton steps ({} O(N) evals), {cold_secs:.3} s",
        best.outer_evals, best.distinct_thetas, best.inner_evals, best.newton_iters,
        best.newton_evals
    );

    // same request again: the family is warm — zero setups, identical bits
    let t1 = Instant::now();
    let warm = tune_theta(&store, &req)?;
    let warm_secs = t1.elapsed().as_secs_f64();
    let wbest = &warm.outputs[0];
    assert_eq!(warm.setups_built, 0, "warm sweep must build nothing");
    assert_eq!(wbest.theta.bits(), best.theta.bits());
    assert_eq!(wbest.score.to_bits(), best.score.to_bits());
    println!("\nwarm re-sweep: 0 setups, bitwise-identical result, {warm_secs:.3} s");
    if warm_secs > 0.0 {
        println!("  cold/warm = {:.1}x", cold_secs / warm_secs);
    }

    // skip the Newton polish for contrast: the grid-only inner loop can
    // only do worse (or tie) at the same outer candidates
    let mut grid_req = req.clone();
    grid_req.refine = RefineKind::None;
    let t2 = Instant::now();
    let grid = tune_theta(&store, &grid_req)?;
    let gbest = &grid.outputs[0];
    println!(
        "\ngrid-only comparison (--refine none): score {:.5} (Newton-refined {:.5}), \
         {} fresh setups, {:.3} s",
        gbest.score,
        best.score,
        grid.setups_built,
        t2.elapsed().as_secs_f64()
    );

    let stats = store.stats();
    println!(
        "\nfamily cache: {} entries, {} hits / {} misses / {} evictions, {} total setups",
        stats.theta_entries, stats.theta_hits, stats.theta_misses, stats.theta_evictions,
        stats.setups
    );

    // sanity: each recovered lengthscale should be within a factor ~3 of
    // truth, and the anisotropy ordering should survive
    for d in 0..2 {
        let ratio = best.theta.get(d) / true_xi2[d];
        if !(0.33..=3.0).contains(&ratio) {
            println!("warning: recovered xi2[{d}] off by {ratio:.2}x (small-N noise)");
        }
    }
    if best.theta.get(0) <= best.theta.get(1) {
        println!("warning: anisotropy ordering not recovered (small-N noise)");
    }
    Ok(())
}

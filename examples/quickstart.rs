//! Quickstart — the end-to-end driver (DESIGN.md §3).
//!
//! Generates a synthetic GP-regression workload with known hyperparameters,
//! pays the O(N^3) eigendecomposition once, tunes (sigma2, lambda2) with a
//! PSO global stage (batched through the PJRT artifacts when present) and
//! Newton refinement (O(N) fused evaluations), cross-checks against the
//! naive O(N^3) baseline on a subsample, and reports held-out prediction
//! quality plus wall-clock for every stage.
//!
//! Run: `cargo run --release --example quickstart [-- --n 1024]`

use std::time::Instant;

use gpml::coordinator::{Backend, Coordinator, GlobalStrategy, ObjectiveKind, TuneRequest};
use gpml::data::{self, SyntheticSpec};
use gpml::kernelfn::Kernel;
use gpml::naive::NaiveEvaluator;
use gpml::runtime::{default_artifact_dir, PjrtRuntime};
use gpml::spectral::{HyperParams, SpectralGp};
use gpml::util::cli::Args;
use gpml::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let n = args.get_usize("n", 1024).map_err(anyhow::Error::msg)?;
    let seed = args.get_usize("seed", 42).map_err(anyhow::Error::msg)? as u64;

    let spec = SyntheticSpec {
        n,
        p: 8,
        kernel: Kernel::Rbf { xi2: 2.0 },
        sigma2: 0.05,
        lambda2: 1.0,
        seed,
    };
    println!("== gpml quickstart ==");
    println!(
        "synthetic GP data: N={} P={} kernel={:?} true sigma2={} true lambda2={}",
        spec.n, spec.p, spec.kernel, spec.sigma2, spec.lambda2
    );
    let ds = data::synthetic(spec, 1);
    let mut rng = Rng::new(seed ^ 0xABCD);
    let (train, test) = ds.split(0.85, &mut rng);
    println!("train N={}, test N={}", train.n(), test.n());

    // --- coordinator: PJRT if artifacts exist, else pure rust ---
    let (mut coord, backend) = match PjrtRuntime::open(default_artifact_dir()) {
        Ok(rt) => {
            println!("backend: PJRT artifacts ({} compiled entries available)", rt.manifest().artifacts.len());
            (Coordinator::with_runtime(rt), Backend::Pjrt)
        }
        Err(e) => {
            println!("backend: pure rust (no artifacts: {e:#})");
            (Coordinator::rust_only(), Backend::Rust)
        }
    };

    // paper-score tune (the reproduction target: same objective as the
    // paper's benchmarks) ...
    let mut req = TuneRequest::new(train.x.clone(), train.ys.clone(), spec.kernel);
    req.backend = backend;
    req.strategy = GlobalStrategy::Pso { particles: 64, iterations: 25 };
    req.seed = seed;

    let t0 = Instant::now();
    let res = coord.tune(&req)?;
    let total = t0.elapsed().as_secs_f64();
    let out = &res.outputs[0];
    println!("\n-- tuning (paper eq. 19 objective) --");
    println!("gram build          : {:>8.3} s", res.gram_seconds);
    println!("eigendecomposition  : {:>8.3} s   (the one-time O(N^3) overhead)", res.eigen_seconds);
    println!(
        "global + newton     : {:>8.3} s   ({} + {} O(N) evaluations)",
        res.tune_seconds, out.global_evals, out.newton_evals
    );
    println!("total               : {:>8.3} s", total);
    println!(
        "paper-score optimum : sigma2 = {:.3e}, lambda2 = {:.3e}, score = {:.4}",
        out.hp.sigma2, out.hp.lambda2, out.score
    );
    println!("  (eq. 19 is boundary-seeking in sigma2 — see DESIGN.md; use the");
    println!("   evidence objective below for hyperparameter recovery)");

    // ... and evidence tune (interior optimum; recovers generating values)
    req.objective = ObjectiveKind::Evidence;
    let res_ev = coord.tune(&req)?;
    let out = &res_ev.outputs[0];
    println!("\n-- tuning (evidence objective, eigen-cache hit: {}) --", res_ev.eigen_cached);
    println!(
        "evidence optimum    : sigma2 = {:.5e} (true {:.5e}), lambda2 = {:.5e} (true {:.5e})",
        out.hp.sigma2, spec.sigma2, out.hp.lambda2, spec.lambda2
    );

    // --- cross-check against the naive O(N^3) evaluator on a subsample ---
    let m = train.n().min(200);
    let sub_x = gpml::linalg::Matrix::from_fn(m, train.p(), |i, j| train.x[(i, j)]);
    let sub_y: Vec<f64> = train.y()[..m].to_vec();
    let k_sub = gpml::kernelfn::gram(spec.kernel, &sub_x);
    let naive = NaiveEvaluator::new(k_sub, sub_y.clone());
    let gp_sub = SpectralGp::fit(spec.kernel, sub_x)?;
    let es_sub = gp_sub.eigensystem(&sub_y);
    let hp = out.hp;
    let (a, b) = (naive.score(hp), es_sub.score(hp));
    println!("\n-- correctness cross-check (N={m} subsample) --");
    println!("naive eq.(15) score : {a:.10}");
    println!("spectral eq.(19)    : {b:.10}   (|diff| = {:.2e})", (a - b).abs());
    assert!((a - b).abs() < 1e-6 * b.abs().max(1.0), "naive and spectral disagree");

    // --- held-out prediction ---
    let gp = SpectralGp::fit(spec.kernel, train.x.clone())?;
    let t_pred = Instant::now();
    let pred = gp.predict_mean(&test.x, train.y(), hp);
    let var = gp.predict_var(&test.x, hp);
    let pred_s = t_pred.elapsed().as_secs_f64();
    let rmse = data::rmse(&pred, test.y());
    let ymean = test.y().iter().sum::<f64>() / test.n() as f64;
    let base_rmse = data::rmse(&vec![ymean; test.n()], test.y());
    // mean negative log predictive density
    let nlpd: f64 = pred
        .iter()
        .zip(&var)
        .zip(test.y())
        .map(|((m, v), y)| 0.5 * ((2.0 * std::f64::consts::PI * v).ln() + (y - m) * (y - m) / v))
        .sum::<f64>()
        / test.n() as f64;
    println!("\n-- held-out prediction ({} points, {:.3} s) --", test.n(), pred_s);
    println!("rmse                : {rmse:.5}  (predict-the-mean baseline: {base_rmse:.5})");
    println!("mean NLPD           : {nlpd:.4}");
    println!("noise floor sigma   : {:.5}", spec.sigma2.sqrt());

    println!("\nquickstart OK");
    Ok(())
}

"""Pallas kernels vs the pure-jnp oracle (``ref.py``).

Hypothesis sweeps shapes and hyperparameter magnitudes; every kernel must
match its oracle to near machine precision, and zero-padding must be exactly
neutral (the property the bucketed AOT runtime relies on).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels import kernelmat, ref, spectral


def _eigsys(n, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 4))
    K = np.asarray(ref.rbf_gram_ref(jnp.array(X), 1.0 + rng.random()))
    y = rng.normal(size=n)
    s, U = np.linalg.eigh(K)
    return jnp.array(s), jnp.array((U.T @ y) ** 2), float(n), float(y @ y)


hp_pos = st.floats(min_value=1e-3, max_value=1e3)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([8, 32, 100, 257, 512]),
    sig=hp_pos,
    lam=hp_pos,
    seed=st.integers(0, 10),
)
def test_score_kernel_matches_ref(n, sig, lam, seed):
    s, y2t, nn, yy = _eigsys(n, seed)
    hp = jnp.array([sig, lam])
    got = float(model.score(s, y2t, hp, nn, yy)[0])
    want = float(ref.spectral_score_ref(s, y2t, nn, yy, sig, lam))
    np.testing.assert_allclose(got, want, rtol=1e-10)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([8, 64, 200, 512]),
    sig=hp_pos,
    lam=hp_pos,
    seed=st.integers(0, 10),
)
def test_fused_kernel_matches_ref(n, sig, lam, seed):
    s, y2t, nn, yy = _eigsys(n, seed)
    hp = jnp.array([sig, lam])
    got = np.asarray(model.fused(s, y2t, hp, nn, yy)[0])
    L = float(ref.spectral_score_ref(s, y2t, nn, yy, sig, lam))
    j_s, j_l = ref.spectral_grad_ref(s, y2t, nn, yy, sig, lam)
    h_ss, h_sl, h_ll = ref.spectral_hess_ref(s, y2t, nn, yy, sig, lam)
    want = np.array([L, j_s, j_l, h_ss, h_sl, h_ll], dtype=np.float64)
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([16, 128, 300]),
    b=st.sampled_from([1, 3, 16, 64]),
    seed=st.integers(0, 5),
)
def test_batched_score_matches_scalar(n, b, seed):
    s, y2t, nn, yy = _eigsys(n, seed)
    rng = np.random.default_rng(seed + 99)
    hps = jnp.array(np.exp(rng.uniform(-3, 3, size=(b, 2))))
    got = np.asarray(model.batched_score(s, y2t, hps, nn, yy)[0])
    want = np.array(
        [
            float(ref.spectral_score_ref(s, y2t, nn, yy, float(h[0]), float(h[1])))
            for h in np.asarray(hps)
        ]
    )
    np.testing.assert_allclose(got, want, rtol=1e-9)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([8, 50, 130]),
    pad_to=st.sampled_from([256, 512]),
    sig=hp_pos,
    lam=hp_pos,
)
def test_zero_padding_is_exactly_neutral(n, pad_to, sig, lam):
    """The bucketed-artifact contract: padding (s, y2t) with zeros changes
    nothing, because log d(0) = 0, all its derivatives vanish, and y2t = 0
    kills the g terms."""
    s, y2t, nn, yy = _eigsys(n, seed=3)
    hp = jnp.array([sig, lam])
    sp = jnp.zeros(pad_to).at[:n].set(s)
    y2p = jnp.zeros(pad_to).at[:n].set(y2t)
    a = np.asarray(model.fused(s, y2t, hp, nn, yy)[0])
    b = np.asarray(model.fused(sp, y2p, hp, nn, yy)[0])
    np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([16, 64, 200, 256]),
    p=st.sampled_from([1, 3, 8, 32]),
    xi2=st.floats(min_value=0.05, max_value=50.0),
    seed=st.integers(0, 5),
)
def test_gram_rbf_matches_ref(n, p, xi2, seed):
    rng = np.random.default_rng(seed)
    X = jnp.array(rng.normal(size=(n, p)))
    got = np.asarray(kernelmat.gram(X, jnp.array([kernelmat.RBF, xi2])))
    want = np.asarray(ref.rbf_gram_ref(X, xi2))
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([16, 100]),
    degree=st.sampled_from([1.0, 2.0, 3.0, 5.0]),
    seed=st.integers(0, 5),
)
def test_gram_poly_matches_ref(n, degree, seed):
    rng = np.random.default_rng(seed)
    X = jnp.array(rng.normal(size=(n, 4)))
    got = np.asarray(kernelmat.gram(X, jnp.array([kernelmat.POLY, degree])))
    want = np.asarray(ref.poly_gram_ref(X, degree))
    np.testing.assert_allclose(got, want, rtol=1e-9)


def test_gram_linear_matches_ref():
    rng = np.random.default_rng(7)
    X = jnp.array(rng.normal(size=(64, 6)))
    got = np.asarray(kernelmat.gram(X, jnp.array([kernelmat.LINEAR, 0.0])))
    np.testing.assert_allclose(got, np.asarray(X @ X.T), rtol=1e-12)


def test_gram_feature_padding_is_exact():
    """Zero feature columns change no inner product / distance (up to BLAS
    accumulation-order noise, which depends on the reduction width)."""
    rng = np.random.default_rng(11)
    X = rng.normal(size=(64, 5))
    Xp = np.zeros((64, 32))
    Xp[:, :5] = X
    a = np.asarray(kernelmat.gram(jnp.array(X), jnp.array([kernelmat.RBF, 2.0])))
    b = np.asarray(kernelmat.gram(jnp.array(Xp), jnp.array([kernelmat.RBF, 2.0])))
    np.testing.assert_allclose(a, b, rtol=1e-13, atol=1e-14)


def test_posterior_var_diag_matches_dense():
    rng = np.random.default_rng(5)
    n = 96
    X = rng.normal(size=(n, 3))
    K = np.asarray(ref.rbf_gram_ref(jnp.array(X), 1.2))
    s, U = np.linalg.eigh(K)
    sig, lam = 0.5, 2.0
    got = np.asarray(
        model.posterior_var_diag(jnp.array(U), jnp.array(s), jnp.array([sig, lam]))[0]
    )
    want = np.diag(np.asarray(ref.dense_posterior_var(jnp.array(K), sig, lam)))
    np.testing.assert_allclose(got, want, rtol=1e-7)


def test_posterior_var_padded_eigenvalues_guarded():
    """Padded (zero) eigenvalues must not produce inf/nan in the pvar kernel."""
    rng = np.random.default_rng(6)
    n, npad = 50, 128
    X = rng.normal(size=(n, 3))
    K = np.asarray(ref.rbf_gram_ref(jnp.array(X), 1.2))
    s, U = np.linalg.eigh(K)
    sp = np.zeros(npad)
    sp[:n] = s
    Up = np.zeros((npad, npad))
    Up[:n, :n] = U
    got = np.asarray(
        model.posterior_var_diag(jnp.array(Up), jnp.array(sp), jnp.array([0.5, 2.0]))[0]
    )
    assert np.all(np.isfinite(got))
    want = np.diag(np.asarray(ref.dense_posterior_var(jnp.array(K), 0.5, 2.0)))
    np.testing.assert_allclose(got[:n], want, rtol=1e-7)


@pytest.mark.parametrize("n", [32, 256, 1024])
def test_score_f32_agrees_loosely(n):
    """f32 path sanity: the kernels are dtype-generic even though the
    shipped artifacts are f64."""
    s, y2t, nn, yy = _eigsys(n, seed=1)
    hp32 = jnp.array([0.7, 1.3], dtype=jnp.float32)
    got = float(
        model.score(
            s.astype(jnp.float32), y2t.astype(jnp.float32), hp32,
            jnp.float32(nn), jnp.float32(yy),
        )[0]
    )
    want = float(ref.spectral_score_ref(s, y2t, nn, yy, 0.7, 1.3))
    np.testing.assert_allclose(got, want, rtol=2e-3)

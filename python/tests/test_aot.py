"""AOT pipeline tests: every entry point lowers to custom-call-free HLO
text (the property the xla_extension-0.5.1 rust runtime depends on), and the
manifest covers the full bucket ladder.
"""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import pytest

jax.config.update("jax_enable_x64", True)

from compile import aot, model


def _lower_text(fn, *specs):
    return aot.to_hlo_text(jax.jit(fn).lower(*specs))


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


@pytest.mark.parametrize("n", [32, 256])
def test_score_lowers_without_custom_calls(n):
    t = _lower_text(model.score, _spec(n), _spec(n), _spec(2), _spec(), _spec())
    assert "custom-call" not in t
    assert "ENTRY" in t


@pytest.mark.parametrize("n", [32, 256])
def test_fused_lowers_without_custom_calls(n):
    t = _lower_text(model.fused, _spec(n), _spec(n), _spec(2), _spec(), _spec())
    assert "custom-call" not in t
    # output is a 1-tuple of a (6,) vector
    assert "(f64[6]" in t


def test_batched_lowers_without_custom_calls():
    t = _lower_text(
        model.batched_score, _spec(64), _spec(64), _spec(16, 2), _spec(), _spec()
    )
    assert "custom-call" not in t
    assert "(f64[16]" in t


def test_gram_lowers_without_custom_calls():
    t = _lower_text(model.gram, _spec(128, 32), _spec(2))
    assert "custom-call" not in t
    assert "(f64[128,128]" in t


def test_pvar_lowers_without_custom_calls():
    t = _lower_text(model.posterior_var_diag, _spec(64, 64), _spec(64), _spec(2))
    assert "custom-call" not in t


def test_build_entries_cover_bucket_ladder():
    entries = aot.build_entries()
    names = [e[0] for e in entries]
    for n in aot.N_BUCKETS:
        assert f"score_n{n}" in names
        assert f"fused_n{n}" in names
        assert f"batched_b{aot.B_BATCH}_n{n}" in names
    for n in aot.NN_BUCKETS:
        assert f"gram_n{n}_p{aot.P_PAD}" in names
        assert f"pvar_n{n}" in names


def test_aot_main_writes_manifest(tmp_path):
    """Run the CLI end-to-end for the two smallest score buckets."""
    env = dict(os.environ)
    repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--only", "score_n32,score_n64"],
        cwd=repo_py, env=env, check=True, capture_output=True,
    )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {"score_n32", "score_n64"}
    for a in manifest["artifacts"]:
        text = (tmp_path / a["file"]).read_text()
        assert "custom-call" not in text
        assert a["n"] in (32, 64)

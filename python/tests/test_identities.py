"""Validate every closed-form identity printed in the paper (eqs. 16-35)
against autodiff of the primitive quantities.  These tests are the
paper-correctness layer: if one of them fails, the *paper's algebra* (or our
transcription of it) is wrong, independent of any pallas/XLA machinery.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile.kernels import ref


def _logd(s, sig, lam):
    return jnp.log((2 * lam * s + sig) / (lam * s + sig))


def _g(s, sig, lam):
    d = (2 * lam * s + sig) / (lam * s + sig)
    return (d * d + 4) / (sig * d)


POINTS = [
    (1.7, 0.6, 2.3),
    (0.01, 0.5, 0.5),
    (25.0, 3.0, 0.05),
    (1e-6, 1.0, 1.0),
    (100.0, 0.01, 10.0),
]


@pytest.mark.parametrize("s,sig,lam", POINTS)
def test_logd_first_derivatives(s, sig, lam):
    """eqs. 22-23 == autodiff of log d."""
    A, B = sig + lam * s, sig + 2 * lam * s
    got_s = jax.grad(_logd, argnums=1)(s, sig, lam)
    got_l = jax.grad(_logd, argnums=2)(s, sig, lam)
    np.testing.assert_allclose(got_s, 1 / B - 1 / A, rtol=1e-10)
    np.testing.assert_allclose(got_l, s * sig / (A * B), rtol=1e-10)


@pytest.mark.parametrize("s,sig,lam", POINTS)
def test_g_first_derivatives(s, sig, lam):
    """eqs. 24-25 == autodiff of g."""
    A, B = sig + lam * s, sig + 2 * lam * s
    got_s = jax.grad(_g, argnums=1)(s, sig, lam)
    got_l = jax.grad(_g, argnums=2)(s, sig, lam)
    eq24 = -4 / sig**2 - (sig**4 - 2 * lam**2 * s**2 * sig**2) / (
        sig**2 * A**2 * B**2
    )
    eq25 = s / A**2 - 4 * s / B**2
    np.testing.assert_allclose(got_s, eq24, rtol=1e-9)
    np.testing.assert_allclose(got_l, eq25, rtol=1e-9, atol=1e-300)


@pytest.mark.parametrize("s,sig,lam", POINTS)
def test_logd_second_derivatives(s, sig, lam):
    """eqs. 30-32 == second autodiff of log d."""
    A, B = sig + lam * s, sig + 2 * lam * s
    ss = jax.grad(jax.grad(_logd, argnums=1), argnums=1)(s, sig, lam)
    sl = jax.grad(jax.grad(_logd, argnums=1), argnums=2)(s, sig, lam)
    ll = jax.grad(jax.grad(_logd, argnums=2), argnums=2)(s, sig, lam)
    np.testing.assert_allclose(ll, s**2 / A**2 - 4 * s**2 / B**2, rtol=1e-9, atol=1e-300)
    np.testing.assert_allclose(sl, s / A**2 - 2 * s / B**2, rtol=1e-9, atol=1e-300)
    np.testing.assert_allclose(ss, 1 / A**2 - 1 / B**2, rtol=1e-9, atol=1e-300)


@pytest.mark.parametrize("s,sig,lam", POINTS)
def test_g_second_derivatives(s, sig, lam):
    """eqs. 33-35 == second autodiff of g."""
    A, B = sig + lam * s, sig + 2 * lam * s
    ss = jax.grad(jax.grad(_g, argnums=1), argnums=1)(s, sig, lam)
    sl = jax.grad(jax.grad(_g, argnums=1), argnums=2)(s, sig, lam)
    ll = jax.grad(jax.grad(_g, argnums=2), argnums=2)(s, sig, lam)
    eq33 = 16 * s**2 / B**3 - 2 * s**2 / A**3
    eq34 = 8 * s / B**3 - 2 * s / A**3
    eq35 = 8 / sig**3 - (
        12 * lam**3 * s**3 * sig**3 + 12 * lam**2 * s**2 * sig**4 - 2 * sig**6
    ) / (sig**3 * A**3 * B**3)
    np.testing.assert_allclose(ll, eq33, rtol=1e-9, atol=1e-300)
    np.testing.assert_allclose(sl, eq34, rtol=1e-9, atol=1e-300)
    np.testing.assert_allclose(ss, eq35, rtol=1e-8)


def _setup(n=60, p=4, seed=0, kernel="rbf"):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, p))
    if kernel == "rbf":
        K = np.asarray(ref.rbf_gram_ref(jnp.array(X), 1.5))
    else:
        K = np.array(ref.poly_gram_ref(jnp.array(X), 2.0))
        K += 1e-8 * np.eye(n)  # poly gram is low-rank; keep eigh stable
    y = rng.normal(size=n)
    s, U = np.linalg.eigh(K)
    y2t = (U.T @ y) ** 2
    return K, y, s, y2t


@pytest.mark.parametrize("kernel", ["rbf", "poly"])
@pytest.mark.parametrize("sig,lam", [(0.7, 1.3), (0.05, 4.0), (3.0, 0.2)])
def test_eq19_equals_eq15(kernel, sig, lam):
    """Proposition 2.1: the spectral score == the dense eq. (15) exactly
    (not merely up to a constant)."""
    K, y, s, y2t = _setup(kernel=kernel)
    dense = ref.dense_score(jnp.array(K), jnp.array(y), sig, lam)
    spec = ref.spectral_score_ref(
        jnp.array(s), jnp.array(y2t), float(len(y)), float(y @ y), sig, lam
    )
    np.testing.assert_allclose(float(spec), float(dense), rtol=1e-8)


def test_eq16_residual_identity():
    """(mu_y - y) = (Sigma_y - 2 sigma^2 I) y / sigma^2  (pre-eq. 16)."""
    K, y, _, _ = _setup()
    sig, lam = 0.9, 1.7
    n = len(y)
    Sy = np.asarray(ref.dense_sigma_y(jnp.array(K), sig, lam))
    mu = np.asarray(ref.dense_mu_y(jnp.array(K), jnp.array(y), sig, lam))
    lhs = mu - y
    rhs = (Sy - 2 * sig * np.eye(n)) @ y / sig
    np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("sig,lam", [(0.7, 1.3), (0.1, 2.5)])
def test_prop22_grad_vs_dense_autodiff(sig, lam):
    """Proposition 2.2 == jax.grad of the dense eq. (15)."""
    K, y, s, y2t = _setup()
    n, yy = float(len(y)), float(y @ y)
    want = ref.dense_grad(jnp.array(K), jnp.array(y), sig, lam)
    got = ref.spectral_grad_ref(jnp.array(s), jnp.array(y2t), n, yy, sig, lam)
    np.testing.assert_allclose(float(got[0]), float(want[0]), rtol=1e-7)
    np.testing.assert_allclose(float(got[1]), float(want[1]), rtol=1e-7)


@pytest.mark.parametrize("sig,lam", [(0.7, 1.3), (0.1, 2.5)])
def test_prop23_hess_vs_dense_autodiff(sig, lam):
    """Proposition 2.3 == jax.hessian of the dense eq. (15)."""
    K, y, s, y2t = _setup()
    n, yy = float(len(y)), float(y @ y)
    want = np.asarray(ref.dense_hess(jnp.array(K), jnp.array(y), sig, lam))
    h_ss, h_sl, h_ll = ref.spectral_hess_ref(
        jnp.array(s), jnp.array(y2t), n, yy, sig, lam
    )
    np.testing.assert_allclose(float(h_ss), want[0, 0], rtol=1e-6)
    np.testing.assert_allclose(float(h_sl), want[0, 1], rtol=1e-6)
    np.testing.assert_allclose(float(h_ll), want[1, 1], rtol=1e-6)


def test_prop24_posterior_variance():
    """Prop. 2.4: diag(U Q U') == diag(Sigma_c) from eq. (36)."""
    K, y, s, y2t = _setup()
    sig, lam = 0.8, 1.1
    _, U = np.linalg.eigh(K)
    want = np.diag(np.asarray(ref.dense_posterior_var(jnp.array(K), sig, lam)))
    got = np.asarray(
        ref.spectral_posterior_var_diag_ref(jnp.array(s), jnp.array(U), sig, lam)
    )
    np.testing.assert_allclose(got, want, rtol=1e-7)


def test_d_and_g_are_the_claimed_eigenvalues():
    """d_i are eigenvalues of Sigma_y / sigma^2; g_i of
    (sigma^-4 Sigma_y + 4 Sigma_y^-1)."""
    K, y, s, _ = _setup(n=30)
    sig, lam = 0.6, 2.0
    Sy = np.asarray(ref.dense_sigma_y(jnp.array(K), sig, lam))
    d_want = np.sort(np.linalg.eigvalsh(Sy / sig))
    d_got = np.sort(np.asarray(ref._d(jnp.array(s), sig, lam)))
    np.testing.assert_allclose(d_got, d_want, rtol=1e-8)
    M = Sy / sig**2 + 4 * np.linalg.inv(Sy)
    g_want = np.sort(np.linalg.eigvalsh(M))
    g_got = np.sort(np.asarray(ref._g(jnp.array(s), sig, lam)))
    np.testing.assert_allclose(g_got, g_want, rtol=1e-8)

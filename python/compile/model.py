"""Layer-2 JAX entry points: the paper's score function and derivatives.

Each function here composes a Layer-1 pallas kernel with the scalar
"closure" terms of Propositions 2.1-2.3 (the terms that depend on the true
N and y'y rather than on the eigenvalues) so that a single compiled bucket
serves any dataset size <= bucket via zero-padding.

These are the functions ``aot.py`` lowers to HLO text; the rust runtime
executes them through PJRT.  Argument convention (all f64):

    s    (N,)   eigenvalues of K, zero-padded to the bucket
    y2t  (N,)   squared projected targets (U'y)^2, zero-padded
    hp   (2,)   [sigma2, lambda2]          -- or (B, 2) for the batch
    n    ()     true number of examples (as a float)
    yy   ()     y'y of the unpadded targets
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import kernelmat, spectral

jax.config.update("jax_enable_x64", True)


def score(s, y2t, hp, n, yy):
    """Eq. (19): L_y = N log sigma2 + sum_i (log d_i + y2_i g_i) - 4 y'y/sigma2.

    Returns a 1-tuple ``(L,)`` (AOT lowering uses return_tuple=True)."""
    sigma2 = hp[0]
    core = spectral.score_core(s, y2t, hp)[0]
    return (n * jnp.log(sigma2) + core - 4.0 * yy / sigma2,)


def fused(s, y2t, hp, n, yy):
    """Score + Jacobian + Hessian in one pass (Props 2.1-2.3).

    Returns a 1-tuple of a (6,) vector:
      [L, dL/dsigma2, dL/dlambda2, d2L/dsigma2^2, d2L/dsigma2 dlambda2,
       d2L/dlambda2^2].
    """
    sigma2 = hp[0]
    c = spectral.fused_core(s, y2t, hp)
    out = jnp.stack(
        [
            n * jnp.log(sigma2) + c[0] - 4.0 * yy / sigma2,            # eq. 19
            n / sigma2 + 4.0 * yy / sigma2**2 + c[1],                  # eq. 20
            c[2],                                                      # eq. 21
            -n / sigma2**2 - 8.0 * yy / sigma2**3 + c[3],              # eq. 28
            c[4],                                                      # eq. 27
            c[5],                                                      # eq. 26
        ]
    )
    return (out,)


def batched_score(s, y2t, hps, n, yy):
    """Eq. (19) at a (B, 2) batch of hyperparameter points -> ((B,),)."""
    sigma2 = hps[:, 0]
    core = spectral.batched_score_core(s, y2t, hps)
    return (n * jnp.log(sigma2) + core - 4.0 * yy / sigma2,)


def gram(X, hp):
    """Gram matrix of the (padded) inputs; hp = [family_code, theta]."""
    return (kernelmat.gram(X, hp),)


def posterior_var_diag(U, s, hp):
    """Prop. 2.4: diag(Sigma_c) in O(N) per element."""
    return (spectral.posterior_var_diag(U, s, hp),)

"""Pure-jnp correctness oracles for the spectral marginal-likelihood kernels.

Two independent layers of ground truth:

1. ``dense_*`` — the paper's eq. (15) evaluated literally: build
   ``Sigma_y``, invert it, take the slogdet.  O(N^3).  Derivatives come
   from ``jax.grad`` / ``jax.hessian`` of the dense score, so they do not
   share *any* algebra with the spectral identities.
2. ``spectral_*_ref`` — straightforward ``jnp`` implementations of the
   paper's O(N) identities (Propositions 2.1-2.3), without pallas.

The pallas kernels in ``spectral.py`` are tested against (2), and (2) is
tested against (1); together this validates both the paper's identities and
our kernels.

All functions are f64 (the sigma^8 / lambda^8 order terms in eqs. 24/35
underflow f32 for ill-scaled inputs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------------------
# Dense formulation (paper eqs. 10, 11, 15)
# ---------------------------------------------------------------------------

def dense_sigma_y(K: jnp.ndarray, sigma2, lam2) -> jnp.ndarray:
    """Sigma_y = sigma^2 (K (K + sigma^2/lambda^2 I)^{-1} + I)   (eq. 11)."""
    n = K.shape[0]
    M = K + (sigma2 / lam2) * jnp.eye(n, dtype=K.dtype)
    return sigma2 * (K @ jnp.linalg.inv(M) + jnp.eye(n, dtype=K.dtype))


def dense_mu_y(K: jnp.ndarray, y: jnp.ndarray, sigma2, lam2) -> jnp.ndarray:
    """mu_y = K (K + sigma^2/lambda^2 I)^{-1} y   (eq. 10)."""
    n = K.shape[0]
    M = K + (sigma2 / lam2) * jnp.eye(n, dtype=K.dtype)
    return K @ jnp.linalg.solve(M, y)


def dense_score(K: jnp.ndarray, y: jnp.ndarray, sigma2, lam2):
    """L_y = log|Sigma_y| + (mu_y - y)' Sigma_y^{-1} (mu_y - y)   (eq. 15)."""
    Sy = dense_sigma_y(K, sigma2, lam2)
    r = dense_mu_y(K, y, sigma2, lam2) - y
    sign, logdet = jnp.linalg.slogdet(Sy)
    return logdet + r @ jnp.linalg.solve(Sy, r)


def dense_grad(K, y, sigma2, lam2):
    """(dL/dsigma2, dL/dlambda2) by autodiff of the dense score."""
    g = jax.grad(lambda s, l: dense_score(K, y, s, l), argnums=(0, 1))
    return g(jnp.float64(sigma2), jnp.float64(lam2))


def dense_hess(K, y, sigma2, lam2):
    """2x2 Hessian of the dense score by autodiff."""
    f = lambda hp: dense_score(K, y, hp[0], hp[1])
    return jax.hessian(f)(jnp.array([sigma2, lam2], dtype=jnp.float64))


def dense_posterior_var(K: jnp.ndarray, sigma2, lam2) -> jnp.ndarray:
    """Sigma_c = sigma^2 (K + sigma^2/lambda^2 I)^{-1} K^{-1}   (eq. 36)."""
    n = K.shape[0]
    M = K + (sigma2 / lam2) * jnp.eye(n, dtype=K.dtype)
    return sigma2 * jnp.linalg.inv(M) @ jnp.linalg.inv(K)


# ---------------------------------------------------------------------------
# Spectral formulation (Propositions 2.1-2.4), plain jnp
# ---------------------------------------------------------------------------

def _d(s, sigma2, lam2):
    """d_i = (2 lam2 s + sigma2)/(lam2 s + sigma2): eigenvalues of Sigma_y/sigma2."""
    return (2.0 * lam2 * s + sigma2) / (lam2 * s + sigma2)


def _g(s, sigma2, lam2):
    """g_i = (d^2 + 4)/(sigma2 d): eigenvalues of sigma^-4 Sigma_y + 4 Sigma_y^-1."""
    d = _d(s, sigma2, lam2)
    return (d * d + 4.0) / (sigma2 * d)


def spectral_score_ref(s, y2t, n, yy, sigma2, lam2):
    """Proposition 2.1 (eq. 19). ``s``: eigenvalues of K; ``y2t``: (U'y)_i^2;
    ``n``: true number of examples; ``yy``: y'y."""
    core = jnp.sum(jnp.log(_d(s, sigma2, lam2)) + y2t * _g(s, sigma2, lam2))
    return n * jnp.log(sigma2) + core - 4.0 * yy / sigma2


def spectral_grad_ref(s, y2t, n, yy, sigma2, lam2):
    """Proposition 2.2 (eqs. 20-25)."""
    A = sigma2 + lam2 * s
    B = sigma2 + 2.0 * lam2 * s
    dlogd_ds = 1.0 / B - 1.0 / A                                   # eq. 22
    dlogd_dl = s * sigma2 / (A * B)                                # eq. 23
    dg_ds = -4.0 / sigma2**2 - (
        sigma2**4 - 2.0 * lam2**2 * s**2 * sigma2**2
    ) / (sigma2**2 * A**2 * B**2)                                  # eq. 24
    dg_dl = s / A**2 - 4.0 * s / B**2                              # eq. 25
    dL_ds = n / sigma2 + 4.0 * yy / sigma2**2 + jnp.sum(dlogd_ds + y2t * dg_ds)
    dL_dl = jnp.sum(dlogd_dl + y2t * dg_dl)
    return dL_ds, dL_dl


def spectral_hess_ref(s, y2t, n, yy, sigma2, lam2):
    """Proposition 2.3 (eqs. 26-35). Returns (d2_ss, d2_sl, d2_ll)."""
    A = sigma2 + lam2 * s
    B = sigma2 + 2.0 * lam2 * s
    d2logd_ll = s**2 / A**2 - 4.0 * s**2 / B**2                    # eq. 30
    d2logd_sl = s / A**2 - 2.0 * s / B**2                          # eq. 31
    d2logd_ss = 1.0 / A**2 - 1.0 / B**2                            # eq. 32
    d2g_ll = 16.0 * s**2 / B**3 - 2.0 * s**2 / A**3                # eq. 33
    d2g_sl = 8.0 * s / B**3 - 2.0 * s / A**3                       # eq. 34
    d2g_ss = 8.0 / sigma2**3 - (
        12.0 * lam2**3 * s**3 * sigma2**3
        + 12.0 * lam2**2 * s**2 * sigma2**4
        - 2.0 * sigma2**6
    ) / (sigma2**3 * A**3 * B**3)                                  # eq. 35
    h_ll = jnp.sum(d2logd_ll + y2t * d2g_ll)                       # eq. 26
    h_sl = jnp.sum(d2logd_sl + y2t * d2g_sl)                       # eq. 27
    h_ss = (
        -n / sigma2**2
        - 8.0 * yy / sigma2**3
        + jnp.sum(d2logd_ss + y2t * d2g_ss)
    )                                                              # eq. 28
    return h_ss, h_sl, h_ll


def spectral_posterior_var_diag_ref(s, U, sigma2, lam2):
    """Proposition 2.4: diag(Sigma_c) = diag(U Q U'), q_i = sigma2*lam2 /
    ((lam2 s_i + sigma2) s_i).  O(N) per requested element."""
    q = sigma2 * lam2 / ((lam2 * s + sigma2) * s)
    return jnp.sum(U * U * q[None, :], axis=1)


def rbf_gram_ref(X, xi2):
    """RBF Gram matrix  K[i,j] = exp(-||x_i - x_j||^2 / (2 xi2))."""
    sq = jnp.sum(X * X, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * X @ X.T
    d2 = jnp.maximum(d2, 0.0)
    return jnp.exp(-d2 / (2.0 * xi2))


def poly_gram_ref(X, degree):
    """Polynomial Gram matrix  K[i,j] = (<x_i, x_j> + 1)^degree."""
    return (X @ X.T + 1.0) ** degree

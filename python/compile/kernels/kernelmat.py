"""Layer-1 Pallas kernel: Gram-matrix construction.

Builds the RBF (or polynomial / linear) kernel matrix from the padded input
matrix ``X`` in (BI, BJ) output tiles.  TPU mapping: each tile is an
MXU-shaped ``(BI, P) @ (P, BJ)`` matmul (the cross-term of the
``||x||^2 + ||y||^2 - 2<x,y>`` decomposition) followed by VPU elementwise
exp — the same schedule a CUDA version would express with threadblocks is
expressed here with a BlockSpec grid over output tiles.

Feature padding with zero columns is exact for all three kernels: zeros
change neither inner products nor squared distances (the polynomial/linear
kernels add their constant after the dot product).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

BLOCK_I = 128
BLOCK_J = 128

# Kernel-family codes shared with Layer 2 / the rust side (manifest.json).
RBF, POLY, LINEAR = 0.0, 1.0, 2.0


def _gram_kernel(xi_ref, xj_ref, hp_ref, o_ref):
    """hp = [family, theta]; theta = xi2 bandwidth (RBF) or degree (poly)."""
    family = hp_ref[0]
    theta = hp_ref[1]
    xi = xi_ref[...]                       # (BI, P)
    xj = xj_ref[...]                       # (BJ, P)
    cross = jnp.dot(xi, xj.T)              # MXU tile
    sqi = jnp.sum(xi * xi, axis=1)[:, None]
    sqj = jnp.sum(xj * xj, axis=1)[None, :]
    d2 = jnp.maximum(sqi + sqj - 2.0 * cross, 0.0)
    rbf = jnp.exp(-d2 / (2.0 * theta))
    poly = (cross + 1.0) ** theta
    lin = cross
    o_ref[...] = jnp.where(family == RBF, rbf, jnp.where(family == POLY, poly, lin))


def gram(X: jnp.ndarray, hp: jnp.ndarray) -> jnp.ndarray:
    """Full (N, N) Gram matrix; ``hp = [family_code, theta]`` runtime input."""
    n, p = X.shape
    # tiles must divide n exactly (the grid truncates otherwise); bucket
    # sizes are powers of two >= 32 so this is BLOCK_I/J in production.
    bi = BLOCK_I if n % BLOCK_I == 0 else n
    bj = BLOCK_J if n % BLOCK_J == 0 else n
    return pl.pallas_call(
        _gram_kernel,
        grid=(n // bi, n // bj),
        in_specs=[
            pl.BlockSpec((bi, p), lambda i, j: (i, 0)),
            pl.BlockSpec((bj, p), lambda i, j: (j, 0)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), X.dtype),
        interpret=True,
    )(X, X, hp)

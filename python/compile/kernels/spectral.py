"""Layer-1 Pallas kernels: the paper's O(N) spectral reductions.

Each kernel consumes the eigendecomposition products of the Gram matrix —
the eigenvalue vector ``s`` and the squared projected targets
``y2t = (U'y)^2`` — plus the hyperparameter pair ``hp = [sigma2, lambda2]``,
and reduces the per-eigenvalue closed forms of Propositions 2.1-2.3 into
scalar sums.

TPU mapping (DESIGN.md §6): the reduction is expressed as a grid over
N-blocks with VMEM-sized tiles.  Each grid step loads a ``(BLK,)`` slice of
``s`` and ``y2t`` into VMEM, evaluates the rational per-eigenvalue terms on
the VPU, and accumulates a partial sum into the (tiny) output block that
stays resident across the whole grid.  ``interpret=True`` everywhere: on the
CPU PJRT backend a Mosaic custom-call cannot run, so the kernels lower to
plain HLO (see /opt/xla-example/README.md).

Zero-padding neutrality: ``s = 0`` gives ``d = 1`` so ``log d`` and all six
of its derivatives vanish; ``y2t = 0`` kills every ``g`` term.  A single
compiled bucket therefore serves any true N <= bucket (the closure terms use
the *true* N and y'y which are runtime scalars added by Layer 2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# Default eigenvalue-block size.  8 bytes * BLK * ~4 live vectors ≈ 8 KiB of
# VMEM per step at 256 — far below the ~16 MiB budget; chosen so that even
# the smallest bucket (N=32) divides evenly via min(BLK, N).
BLOCK = 256


def _blk(n: int) -> int:
    """Largest tile that evenly divides ``n`` (grid truncates otherwise).
    Bucket sizes are powers of two so this is BLOCK in production; odd test
    sizes fall back to a single block."""
    return BLOCK if n % BLOCK == 0 else n


# ---------------------------------------------------------------------------
# per-eigenvalue closed forms (shared by all kernels)
# ---------------------------------------------------------------------------

def _terms_score(s, y2, sigma2, lam2):
    """log d_i + y2_i * g_i   (Proposition 2.1)."""
    a = lam2 * s + sigma2
    b = 2.0 * lam2 * s + sigma2
    d = b / a
    g = (d * d + 4.0) / (sigma2 * d)
    return jnp.log(d) + y2 * g


def _terms_jac(s, y2, sigma2, lam2):
    """(eq.20 summand, eq.21 summand)  (Proposition 2.2)."""
    A = sigma2 + lam2 * s
    B = sigma2 + 2.0 * lam2 * s
    dlogd_ds = 1.0 / B - 1.0 / A                                    # eq. 22
    dlogd_dl = s * sigma2 / (A * B)                                 # eq. 23
    dg_ds = -4.0 / (sigma2 * sigma2) - (
        sigma2**4 - 2.0 * lam2 * lam2 * s * s * sigma2 * sigma2
    ) / (sigma2 * sigma2 * A * A * B * B)                           # eq. 24
    dg_dl = s / (A * A) - 4.0 * s / (B * B)                         # eq. 25
    return dlogd_ds + y2 * dg_ds, dlogd_dl + y2 * dg_dl


def _terms_hess(s, y2, sigma2, lam2):
    """(eq.28, eq.27, eq.26 summands) = (ss, sl, ll)  (Proposition 2.3)."""
    A = sigma2 + lam2 * s
    B = sigma2 + 2.0 * lam2 * s
    A2, B2 = A * A, B * B
    A3, B3 = A2 * A, B2 * B
    s2 = s * s
    d2logd_ll = s2 / A2 - 4.0 * s2 / B2                             # eq. 30
    d2logd_sl = s / A2 - 2.0 * s / B2                               # eq. 31
    d2logd_ss = 1.0 / A2 - 1.0 / B2                                 # eq. 32
    d2g_ll = 16.0 * s2 / B3 - 2.0 * s2 / A3                         # eq. 33
    d2g_sl = 8.0 * s / B3 - 2.0 * s / A3                            # eq. 34
    s6 = sigma2**3
    d2g_ss = 8.0 / s6 - (
        12.0 * lam2**3 * s2 * s * s6
        + 12.0 * lam2 * lam2 * s2 * sigma2**4
        - 2.0 * sigma2**6
    ) / (s6 * A3 * B3)                                              # eq. 35
    return (
        d2logd_ss + y2 * d2g_ss,
        d2logd_sl + y2 * d2g_sl,
        d2logd_ll + y2 * d2g_ll,
    )


# ---------------------------------------------------------------------------
# score kernel: out[0] = sum_i log d_i + y2_i g_i
# ---------------------------------------------------------------------------

def _score_kernel(s_ref, y2_ref, hp_ref, o_ref):
    sigma2 = hp_ref[0]
    lam2 = hp_ref[1]
    part = jnp.sum(_terms_score(s_ref[...], y2_ref[...], sigma2, lam2))

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[0] = 0.0

    o_ref[0] += part


def score_core(s: jnp.ndarray, y2t: jnp.ndarray, hp: jnp.ndarray) -> jnp.ndarray:
    """Pallas reduction of the eigenvalue sum in eq. (19); returns shape (1,)."""
    n = s.shape[0]
    blk = _blk(n)
    return pl.pallas_call(
        _score_kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), s.dtype),
        interpret=True,
    )(s, y2t, hp)


# ---------------------------------------------------------------------------
# fused kernel: score + Jacobian + Hessian sums in one pass
# out = [score_core, jac_s, jac_l, hess_ss, hess_sl, hess_ll]
# ---------------------------------------------------------------------------

def _fused_kernel(s_ref, y2_ref, hp_ref, o_ref):
    sigma2 = hp_ref[0]
    lam2 = hp_ref[1]
    s = s_ref[...]
    y2 = y2_ref[...]
    t0 = jnp.sum(_terms_score(s, y2, sigma2, lam2))
    j_s, j_l = _terms_jac(s, y2, sigma2, lam2)
    h_ss, h_sl, h_ll = _terms_hess(s, y2, sigma2, lam2)
    part = jnp.stack(
        [t0, jnp.sum(j_s), jnp.sum(j_l), jnp.sum(h_ss), jnp.sum(h_sl), jnp.sum(h_ll)]
    )

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros((6,), dtype=part.dtype)

    o_ref[...] += part


def fused_core(s: jnp.ndarray, y2t: jnp.ndarray, hp: jnp.ndarray) -> jnp.ndarray:
    """One-pass score/Jacobian/Hessian eigenvalue sums; returns shape (6,)."""
    n = s.shape[0]
    blk = _blk(n)
    return pl.pallas_call(
        _fused_kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((6,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((6,), s.dtype),
        interpret=True,
    )(s, y2t, hp)


# ---------------------------------------------------------------------------
# batched score: B hyperparameter points against one eigensystem.
# This is the global-search wavefront (grid / PSO swarm): the coordinator
# amortizes one PJRT dispatch over the whole swarm.
# ---------------------------------------------------------------------------

def _batched_kernel(s_ref, y2_ref, hp_ref, o_ref):
    s = s_ref[...][None, :]          # (1, BLK)
    y2 = y2_ref[...][None, :]        # (1, BLK)
    sigma2 = hp_ref[...][:, 0:1]     # (B, 1)
    lam2 = hp_ref[...][:, 1:2]       # (B, 1)
    part = jnp.sum(_terms_score(s, y2, sigma2, lam2), axis=1)  # (B,)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def batched_score_core(
    s: jnp.ndarray, y2t: jnp.ndarray, hps: jnp.ndarray
) -> jnp.ndarray:
    """Eigenvalue sums of eq. (19) for a (B, 2) batch of hyperparameter
    points; returns shape (B,)."""
    n = s.shape[0]
    b = hps.shape[0]
    blk = _blk(n)
    return pl.pallas_call(
        _batched_kernel,
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((b, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), s.dtype),
        interpret=True,
    )(s, y2t, hps)


# ---------------------------------------------------------------------------
# posterior variance diagonal (Proposition 2.4):
#   diag(Sigma_c)[i] = sum_j U[i,j]^2 q_j,   q_j = sigma2 lam2 / ((lam2 s_j + sigma2) s_j)
# Grid over row blocks; each step loads a (BI, N) slab of U.
# ---------------------------------------------------------------------------

def _pvar_kernel(u_ref, s_ref, hp_ref, o_ref):
    sigma2 = hp_ref[0]
    lam2 = hp_ref[1]
    s = s_ref[...]
    # guard padded (zero) eigenvalues: q is only meaningful for s > 0, and
    # padded columns of U are zero anyway, so clamp the denominator.
    denom = (lam2 * s + sigma2) * s
    q = jnp.where(s > 0.0, sigma2 * lam2 / jnp.where(s > 0.0, denom, 1.0), 0.0)
    u = u_ref[...]
    o_ref[...] = jnp.sum(u * u * q[None, :], axis=1)


def posterior_var_diag(
    U: jnp.ndarray, s: jnp.ndarray, hp: jnp.ndarray
) -> jnp.ndarray:
    """diag(Sigma_c) via Prop. 2.4; returns shape (N,)."""
    n = s.shape[0]
    bi = _blk(n)
    return pl.pallas_call(
        _pvar_kernel,
        grid=(n // bi,),
        in_specs=[
            pl.BlockSpec((bi, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bi,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), s.dtype),
        interpret=True,
    )(U, s, hp)

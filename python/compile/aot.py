"""AOT pipeline: lower every Layer-2 entry point to HLO **text** artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser on the rust side
(``HloModuleProto::from_text_file``) reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage (normally via ``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts

Writes one ``<name>.hlo.txt`` per (entry point, bucket) plus a
``manifest.json`` that the rust runtime reads to discover buckets and
shapes.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

F64 = jnp.float64

# Bucket ladder — matches the paper's simulation sweep (N = 32 .. 8192 on a
# log2 scale).  A dataset of size N is served by the smallest bucket >= N.
N_BUCKETS = [32, 64, 128, 256, 512, 1024, 2048, 4096, 8192]
# Global-search wavefront width (grid/PSO swarm size per dispatch).
B_BATCH = 64
# Feature-dimension ceiling for the gram artifact (features zero-pad exactly).
P_PAD = 32
# The (N, N) artifacts (gram, posterior-variance) stop earlier: an f64
# 8192 x 8192 literal is 512 MiB per buffer, past the point where the rust
# eigensolver dominates anyway.
NN_BUCKETS = [n for n in N_BUCKETS if n <= 4096]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F64)


def build_entries():
    """(name, jax_fn, example_args, meta) for every artifact."""
    entries = []
    for n in N_BUCKETS:
        vec, hp, sc = _spec(n), _spec(2), _spec()
        entries.append(
            (f"score_n{n}", model.score, (vec, vec, hp, sc, sc),
             {"entry": "score", "n": n})
        )
        entries.append(
            (f"fused_n{n}", model.fused, (vec, vec, hp, sc, sc),
             {"entry": "fused", "n": n})
        )
        hps = _spec(B_BATCH, 2)
        entries.append(
            (f"batched_b{B_BATCH}_n{n}", model.batched_score,
             (vec, vec, hps, sc, sc),
             {"entry": "batched_score", "n": n, "b": B_BATCH})
        )
    for n in NN_BUCKETS:
        entries.append(
            (f"gram_n{n}_p{P_PAD}", model.gram, (_spec(n, P_PAD), _spec(2)),
             {"entry": "gram", "n": n, "p": P_PAD})
        )
        entries.append(
            (f"pvar_n{n}", model.posterior_var_diag,
             (_spec(n, n), _spec(n), _spec(2)),
             {"entry": "posterior_var_diag", "n": n})
        )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None,
        help="comma-separated artifact-name filter (substring match)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"dtype": "f64", "b_batch": B_BATCH, "p_pad": P_PAD,
                "artifacts": []}
    for name, fn, specs, meta in build_entries():
        if args.only and not any(tok in name for tok in args.only.split(",")):
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        rec = {"name": name, "file": fname, **meta}
        manifest["artifacts"].append(rec)
        print(f"  wrote {fname:<28} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()

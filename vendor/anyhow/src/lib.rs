//! Offline shim for the `anyhow` error crate (DESIGN.md §5: no crates.io
//! access in this image).  Implements the subset the repo uses with the
//! same semantics:
//!
//! - [`Error`]: an opaque error value built from any message or any
//!   `std::error::Error`, carrying a context chain.
//! - [`Result<T>`]: alias for `Result<T, Error>`.
//! - [`anyhow!`]: construct an [`Error`] from a format string or value.
//! - [`Context`]: `.context(..)` / `.with_context(|| ..)` on results.
//! - `Display` shows the outermost context; the `{:#}` alternate form
//!   shows the whole chain down to the root cause, matching the upstream
//!   crate's formatting contract that `main.rs` and the examples rely on.
//!
//! Like upstream, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket
//! `From<E: std::error::Error>` conversion (and therefore `?` on
//! `io::Error`, eigensolver errors, etc.) coherent.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: root-cause message plus a context chain
/// (innermost-first storage; displayed outermost-first).
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Build from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), context: Vec::new() }
    }

    /// Wrap with an outer context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        &self.msg
    }

    /// Context layers plus root cause, outermost first (for tests/logs).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.context
            .iter()
            .rev()
            .map(String::as_str)
            .chain(std::iter::once(self.msg.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // "{:#}": the full chain, `outer: inner: root`.
            for (i, layer) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{layer}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.chain().next().unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirrors upstream: message, then a caused-by list.
        let mut layers = self.chain();
        write!(f, "{}", layers.next().unwrap_or(""))?;
        let rest: Vec<&str> = layers.collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, layer) in rest.iter().enumerate() {
                write!(f, "\n    {i}: {layer}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        // Flatten the source chain into the root message so nothing is
        // lost even though we do not retain the boxed error.
        let mut msg = err.to_string();
        let mut source = err.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Error { msg, context: Vec::new() }
    }
}

/// `.context(..)` / `.with_context(|| ..)` on any result whose error
/// converts into [`Error`].
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string (with captures), a format
/// string plus arguments, or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    #[test]
    fn macro_forms() {
        let a: Error = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let what = "thing";
        let b: Error = anyhow!("missing {what}");
        assert_eq!(b.to_string(), "missing thing");
        let c: Error = anyhow!("{} of {}", 2, 3);
        assert_eq!(c.to_string(), "2 of 3");
        let d: Error = anyhow!(String::from("owned"));
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn io_fail() -> Result<()> {
            std::fs::read_to_string("/definitely/not/a/real/path/gpml")?;
            Ok(())
        }
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn context_chain_and_alternate_format() {
        let base: Result<()> = Err(anyhow!("root cause"));
        let err = base
            .context("inner op")
            .with_context(|| format!("outer op {}", 7))
            .unwrap_err();
        assert_eq!(err.to_string(), "outer op 7");
        assert_eq!(format!("{err:#}"), "outer op 7: inner op: root cause");
        assert_eq!(err.root_cause(), "root cause");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn result_termination_compatible() {
        // fn main() -> anyhow::Result<()> requires Error: Debug; exercise
        // the Debug impl on a bare error.
        let e: Error = anyhow!("boom");
        assert_eq!(format!("{e:?}"), "boom");
    }
}
